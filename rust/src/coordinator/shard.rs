//! Dimension-sharded aggregation: partition the parameter space `0..d`
//! into `S` contiguous shards, each owning its own slice of the
//! aggregation state, its own participation counters (inside the slice
//! sink) and its own [`ScratchPool`], behind the same
//! `begin_round`/`absorb`/`finish_round` streaming interface the
//! single-lane [`Aggregator`] exposes.
//!
//! This is the ROADMAP's million-client seam: the server-side cost of a
//! round is an O(d) sweep per client update (the Eq. 5 pseudo-count
//! accumulation), and a single absorb thread caps throughput at one
//! socket's memory bandwidth. Splitting `d` at shard boundaries makes the
//! absorb stage embarrassingly parallel in the dimension axis — the same
//! structure FedPM-style mask aggregation has on paper, where every
//! coordinate's pseudo-count is independent of every other's.
//!
//! ## Shape
//!
//! A [`ShardedAggregator`] owns `S` lanes. Between rounds each lane is a
//! quiescent `(range, sink, pool)` triple; `begin_round` moves every sink
//! onto its own **absorb lane thread** and hands out a clonable
//! [`ShardRouter`]. Routing a decoded record copies each shard's
//! sub-range into a buffer leased from that shard's pool and enqueues it
//! on the lane's bounded channel; the lane thread absorbs sub-updates in
//! arrival order and recycles spent buffers into its own pool.
//! `finish_round` closes the lanes, joins the threads, runs each slice
//! sink's `finish_round`, and parks the lanes again — at which point
//! [`ShardedAggregator::into_shards`] hands the slices back for stitching
//! (see `fl::server::MaskServer::adopt_shards`).
//!
//! ## Why sharding preserves bitwise identity
//!
//! Every conforming [`Aggregator`] update rule is **per-coordinate**
//! (pseudo-count adds, slot-ordered FedAvg on scores), so restricting it
//! to a contiguous range commutes with running it over all of `d`: lane
//! `s` performs exactly the arithmetic the single-lane path performs on
//! coordinates `range_s`, in an equivalent order (each lane sees every
//! slot, and the [`Aggregator`] contract already requires arrival-order
//! equivalence). Stitching the slices back is a pure copy. The property
//! suite in `rust/tests/agg_shards.rs` checks bitwise identity across all
//! 8 codecs × both pipeline modes × shard counts {1,2,3,8} under
//! adversarial arrival orders.

use super::aggregate::Aggregator;
use crate::compress::{ScratchPool, Update};
use crate::util::timer::Stopwatch;
use std::ops::Range;
use std::sync::mpsc::{self, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Sub-updates a lane's bounded queue holds before routing backpressures.
/// Memory in the decode→absorb hand-off stays O(cap · d) across all lanes
/// combined (each lane buffers `cap` sub-ranges of length ~d/S).
const LANE_QUEUE_CAP: usize = 4;

/// Partition `0..d` into `shards` contiguous, near-equal ranges (the
/// first `d % shards` ranges are one element longer). The shard count is
/// clamped to `[1, max(d, 1)]` so no lane ever owns an empty range.
///
/// ```
/// use deltamask::coordinator::shard_bounds;
/// assert_eq!(shard_bounds(7, 3), vec![0..3, 3..5, 5..7]);
/// assert_eq!(shard_bounds(6, 1), vec![0..6]);
/// assert_eq!(shard_bounds(2, 8).len(), 2); // clamped: never empty shards
/// ```
pub fn shard_bounds(d: usize, shards: usize) -> Vec<Range<usize>> {
    let s = shards.clamp(1, d.max(1));
    let base = d / s;
    let extra = d % s;
    let mut bounds = Vec::with_capacity(s);
    let mut start = 0;
    for i in 0..s {
        let len = base + usize::from(i < extra);
        bounds.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, d);
    bounds
}

/// What a lane thread sends back when its round ends (normally via
/// `Finish`, or unfinished when the round was aborted).
struct LaneReturn<A> {
    sink: A,
    absorb_secs: f64,
    finished: bool,
}

enum LaneMsg {
    Absorb { slot: usize, update: Update },
    Finish,
}

/// One quiescent shard: its d-range, its slice sink (present between
/// rounds, on the lane thread while a round is in flight) and its
/// dedicated sub-update buffer pool.
struct ShardLane<A> {
    range: Range<usize>,
    sink: Option<A>,
    pool: Arc<ScratchPool>,
    /// Absorb compute seconds this lane spent in the last finished round.
    absorb_secs: f64,
}

/// The shareable per-round routing table: shard ranges, pools and lane
/// queue senders. Cloned into decode workers so they hand each decoded
/// record straight to the absorb lanes without serializing on the
/// draining thread.
#[derive(Clone)]
pub struct ShardRouter {
    lanes: Arc<[RouterLane]>,
}

struct RouterLane {
    range: Range<usize>,
    pool: Arc<ScratchPool>,
    tx: SyncSender<LaneMsg>,
}

impl ShardRouter {
    /// Split `update` at the shard boundaries and enqueue each sub-range
    /// on its shard's absorb lane (leasing the sub-buffer from that
    /// shard's pool). Blocks when a lane's bounded queue is full — that
    /// backpressure is what keeps decode from racing ahead of absorb.
    ///
    /// The caller keeps ownership of the full reconstruction buffer and
    /// should recycle it (`Update::into_vec` → the drain's `ScratchPool`)
    /// once this returns.
    pub fn route(&self, slot: usize, update: &Update) {
        for lane in self.lanes.iter() {
            let sub = match update {
                Update::Mask(v) => Update::Mask(lane.pool.take_copy(&v[lane.range.clone()])),
                Update::ScoreDelta(v) => {
                    Update::ScoreDelta(lane.pool.take_copy(&v[lane.range.clone()]))
                }
            };
            // A send can only fail if the lane exited early, which means
            // its sink panicked (a coordinator bug); the panic surfaces
            // when the lanes are joined, so it is not swallowed here.
            let _ = lane.tx.send(LaneMsg::Absorb { slot, update: sub });
        }
    }

    /// Number of shard lanes this router fans out to.
    pub fn shard_count(&self) -> usize {
        self.lanes.len()
    }
}

/// Lane threads plus the routing table for one in-flight round.
struct RunningRound<A> {
    router: ShardRouter,
    handles: Vec<JoinHandle<LaneReturn<A>>>,
}

/// Dimension-sharded streaming aggregation sink: `S` contiguous shards of
/// the parameter space, each with its own slice sink, participation
/// counters and [`ScratchPool`], absorbed on `S` parallel lane threads.
///
/// Construct it from `(range, slice sink)` pairs tiling `0..d` — for the
/// Bayesian mask server, `fl::server::MaskServer::shard_view` builds the
/// slices and `adopt_shards` stitches them back after the round. Drive it
/// either as a plain [`Aggregator`] (inline `absorb` splits each record
/// and fans it out) or through [`drain_round`](super::drain_round) with
/// [`DrainConfig::shards`](super::DrainConfig) > 1, where the decode
/// workers route records to the lanes directly via [`ShardRouter`].
///
/// ```
/// use deltamask::compress::Update;
/// use deltamask::coordinator::Aggregator;
/// use deltamask::fl::server::MaskServer;
///
/// // Two identical servers; one aggregates the round monolithically,
/// // the other through a 3-shard view — bitwise-identical results.
/// let mut mono = MaskServer::with_theta0(8, 1.0, 0.5);
/// let mut split = mono.clone();
/// let updates = vec![
///     Update::Mask(vec![1.0, 0.0, 1.0, 1.0, 0.0, 1.0, 0.0, 0.0]),
///     Update::Mask(vec![0.0, 0.0, 1.0, 0.0, 1.0, 1.0, 1.0, 0.0]),
/// ];
/// mono.aggregate(&updates);
///
/// let mut view = split.shard_view(3);
/// view.begin_round(2);
/// for (slot, u) in updates.iter().enumerate() {
///     view.absorb(slot, u.clone());
/// }
/// view.finish_round();
/// assert_eq!(view.absorb_secs_by_shard().len(), 3);
/// split.adopt_shards(view);
///
/// assert_eq!(mono.theta_g, split.theta_g); // bitwise
/// assert_eq!(mono.s_g, split.s_g);
/// ```
pub struct ShardedAggregator<A> {
    lanes: Vec<ShardLane<A>>,
    running: Option<RunningRound<A>>,
    /// Full decoded buffers spent by the inline `absorb` path (their
    /// shard sub-ranges already copied out), awaiting reclamation by the
    /// drain loop via [`Aggregator::reclaim_buffer`].
    spent: Vec<Vec<f32>>,
}

impl<A: Aggregator + Send + 'static> ShardedAggregator<A> {
    /// Build a sharded sink from `(range, slice sink)` pairs. The ranges
    /// must tile `0..d` contiguously in order (see [`shard_bounds`]).
    pub fn new(shards: Vec<(Range<usize>, A)>) -> Self {
        assert!(!shards.is_empty(), "at least one shard required");
        let mut expect = 0;
        for (range, _) in &shards {
            assert_eq!(
                range.start, expect,
                "shard ranges must tile 0..d contiguously"
            );
            assert!(range.end >= range.start, "inverted shard range");
            expect = range.end;
        }
        Self {
            lanes: shards
                .into_iter()
                .map(|(range, sink)| ShardLane {
                    range,
                    sink: Some(sink),
                    pool: Arc::new(ScratchPool::new()),
                    absorb_secs: 0.0,
                })
                .collect(),
            running: None,
            spent: Vec::new(),
        }
    }

    /// Spawn the lane threads for one round and build the router.
    fn start_round(&mut self, expected: usize) {
        let mut handles = Vec::with_capacity(self.lanes.len());
        let mut router_lanes = Vec::with_capacity(self.lanes.len());
        for lane in &mut self.lanes {
            let (tx, rx) = mpsc::sync_channel::<LaneMsg>(LANE_QUEUE_CAP);
            let mut sink = lane.sink.take().expect("lane sink present between rounds");
            let pool = Arc::clone(&lane.pool);
            handles.push(std::thread::spawn(move || {
                sink.begin_round(expected);
                let mut absorb_secs = 0.0;
                let mut finished = false;
                while let Ok(msg) = rx.recv() {
                    match msg {
                        LaneMsg::Absorb { slot, update } => {
                            let t = Stopwatch::new();
                            sink.absorb(slot, update);
                            while let Some(buf) = sink.reclaim_buffer() {
                                pool.put(buf);
                            }
                            absorb_secs += t.elapsed_secs();
                        }
                        LaneMsg::Finish => {
                            sink.finish_round();
                            finished = true;
                            break;
                        }
                    }
                }
                // Every sender dropped without `Finish` means the round
                // was aborted: hand the (mid-round) sink back so the next
                // `begin_round` can supersede its state, exactly like an
                // aborted serial round.
                LaneReturn {
                    sink,
                    absorb_secs,
                    finished,
                }
            }));
            router_lanes.push(RouterLane {
                range: lane.range.clone(),
                pool: Arc::clone(&lane.pool),
                tx,
            });
        }
        self.running = Some(RunningRound {
            router: ShardRouter {
                lanes: router_lanes.into(),
            },
            handles,
        });
    }
}

impl<A> ShardedAggregator<A> {
    /// Number of shards (== absorb lanes).
    pub fn shard_count(&self) -> usize {
        self.lanes.len()
    }

    /// Total dimensionality the shards tile.
    pub fn d(&self) -> usize {
        self.lanes.last().map(|l| l.range.end).unwrap_or(0)
    }

    /// The shard ranges, in order.
    pub fn bounds(&self) -> Vec<Range<usize>> {
        self.lanes.iter().map(|l| l.range.clone()).collect()
    }

    /// Absorb compute seconds each lane spent in the last finished round,
    /// indexed by shard. A lopsided split flags dimension imbalance
    /// (e.g. one shard owning all the dense payload coordinates).
    pub fn absorb_secs_by_shard(&self) -> Vec<f64> {
        self.lanes.iter().map(|l| l.absorb_secs).collect()
    }

    /// Tear down an in-flight round without finishing it: drop the lane
    /// queues, join every lane thread and park the (mid-round) sinks back
    /// in their lanes. Safe to call at any time; a no-op between rounds.
    pub fn abort_round(&mut self) {
        let Some(RunningRound { router, handles }) = self.running.take() else {
            return;
        };
        drop(router); // all senders gone → lanes drain their queues and exit
        self.join_lanes(handles);
    }

    /// Decompose into `(range, slice sink)` pairs for stitching back into
    /// the global state. Aborts any round still in flight first.
    pub fn into_shards(mut self) -> Vec<(Range<usize>, A)> {
        self.abort_round();
        std::mem::take(&mut self.lanes)
            .into_iter()
            .map(|lane| {
                (
                    lane.range,
                    lane.sink.expect("lane sink present after abort/finish"),
                )
            })
            .collect()
    }

    /// Join lane threads and park their sinks; propagates lane panics.
    fn join_lanes(&mut self, handles: Vec<JoinHandle<LaneReturn<A>>>) -> bool {
        let mut all_finished = true;
        for (lane, handle) in self.lanes.iter_mut().zip(handles) {
            match handle.join() {
                Ok(ret) => {
                    lane.sink = Some(ret.sink);
                    lane.absorb_secs = ret.absorb_secs;
                    all_finished &= ret.finished;
                }
                Err(panic) => std::panic::resume_unwind(panic),
            }
        }
        all_finished
    }
}

impl<A: Aggregator + Send + 'static> Aggregator for ShardedAggregator<A> {
    fn begin_round(&mut self, expected: usize) {
        // A round left in flight by an aborted drain is superseded, the
        // same tolerance the single-lane sinks give repeated begins.
        self.abort_round();
        self.spent.clear();
        self.start_round(expected);
    }

    /// Inline reference path: split the record at the shard boundaries on
    /// the calling thread and fan the pieces out to the absorb lanes. The
    /// routed drain (`DrainConfig::shards > 1`) bypasses this and calls
    /// [`ShardRouter::route`] from the decode workers instead.
    fn absorb(&mut self, slot: usize, update: Update) {
        assert_eq!(update.len(), self.d(), "update dimensionality mismatch");
        let running = self
            .running
            .as_ref()
            .expect("ShardedAggregator::absorb called before begin_round");
        running.router.route(slot, &update);
        // Sub-ranges are copied out; the full buffer is spent and flows
        // back to the drain's pool via `reclaim_buffer`.
        self.spent.push(update.into_vec());
    }

    fn finish_round(&mut self) {
        let RunningRound { router, handles } = self
            .running
            .take()
            .expect("ShardedAggregator::finish_round called before begin_round");
        // Lane queues are FIFO and every routed sub-update was enqueued
        // before its completion was acknowledged, so `Finish` lands after
        // the round's full absorb set on every lane.
        for lane in router.lanes.iter() {
            let _ = lane.tx.send(LaneMsg::Finish);
        }
        drop(router);
        let finished = self.join_lanes(handles);
        assert!(finished, "a shard lane exited before Finish");
    }

    fn reclaim_buffer(&mut self) -> Option<Vec<f32>> {
        self.spent.pop()
    }

    fn shard_router(&self) -> Option<ShardRouter> {
        self.running.as_ref().map(|r| r.router.clone())
    }

    fn abort_round(&mut self) {
        ShardedAggregator::abort_round(self);
    }
}

impl<A> Drop for ShardedAggregator<A> {
    /// Dropping mid-round (e.g. the drain bailed on a decode error and
    /// the caller discards the view) still joins every lane thread.
    fn drop(&mut self) {
        if let Some(RunningRound { router, handles }) = self.running.take() {
            drop(router);
            for handle in handles {
                // Swallow lane panics during unwinding; double panics abort.
                let _ = handle.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Per-lane spy sink recording what it absorbed.
    #[derive(Default)]
    struct LaneSpy {
        d: usize,
        begun: Option<usize>,
        absorbed: Vec<(usize, Vec<f32>)>,
        finished: bool,
    }

    impl Aggregator for LaneSpy {
        fn begin_round(&mut self, expected: usize) {
            self.begun = Some(expected);
        }

        fn absorb(&mut self, slot: usize, update: Update) {
            assert_eq!(update.len(), self.d);
            self.absorbed.push((slot, update.into_vec()));
        }

        fn finish_round(&mut self) {
            self.finished = true;
        }
    }

    fn spy_shards(d: usize, shards: usize) -> ShardedAggregator<LaneSpy> {
        ShardedAggregator::new(
            shard_bounds(d, shards)
                .into_iter()
                .map(|r| {
                    let spy = LaneSpy {
                        d: r.len(),
                        ..Default::default()
                    };
                    (r, spy)
                })
                .collect(),
        )
    }

    #[test]
    fn bounds_tile_the_space() {
        assert_eq!(shard_bounds(10, 4), vec![0..3, 3..6, 6..8, 8..10]);
        assert_eq!(shard_bounds(3, 3), vec![0..1, 1..2, 2..3]);
        assert_eq!(shard_bounds(5, 1), vec![0..5]);
        // Clamping: more shards than dimensions never yields empty lanes.
        assert_eq!(shard_bounds(2, 5), vec![0..1, 1..2]);
        assert_eq!(shard_bounds(0, 3), vec![0..0]);
        for (d, s) in [(1031, 8), (64, 7), (100, 100)] {
            let bounds = shard_bounds(d, s);
            assert_eq!(bounds.first().unwrap().start, 0);
            assert_eq!(bounds.last().unwrap().end, d);
            for w in bounds.windows(2) {
                assert_eq!(w[0].end, w[1].start, "d={d} s={s}");
                assert!(!w[0].is_empty());
            }
        }
    }

    #[test]
    fn inline_absorb_splits_at_shard_boundaries() {
        let d = 10;
        let mut agg = spy_shards(d, 3); // ranges 0..4, 4..7, 7..10
        agg.begin_round(2);
        let u0: Vec<f32> = (0..d).map(|i| i as f32).collect();
        agg.absorb(0, Update::Mask(u0.clone()));
        agg.absorb(1, Update::ScoreDelta(u0.iter().map(|v| -v).collect()));
        // Spent full buffers flow back through reclaim.
        assert!(agg.reclaim_buffer().is_some());
        assert!(agg.reclaim_buffer().is_some());
        assert!(agg.reclaim_buffer().is_none());
        agg.finish_round();
        let timings = agg.absorb_secs_by_shard();
        assert_eq!(timings.len(), 3);
        let shards = agg.into_shards();
        assert_eq!(shards.len(), 3);
        for (range, spy) in shards {
            assert_eq!(spy.begun, Some(2));
            assert!(spy.finished);
            assert_eq!(spy.absorbed.len(), 2);
            let (slot0, sub0) = &spy.absorbed[0];
            assert_eq!(*slot0, 0);
            assert_eq!(sub0, &u0[range.clone()].to_vec(), "{range:?}");
            let (slot1, sub1) = &spy.absorbed[1];
            assert_eq!(*slot1, 1);
            assert_eq!(sub1.len(), range.len());
        }
    }

    #[test]
    fn abort_round_parks_unfinished_lanes_for_reuse() {
        let mut agg = spy_shards(6, 2);
        agg.begin_round(3);
        agg.absorb(0, Update::Mask(vec![1.0; 6]));
        agg.abort_round(); // two updates never arrive
        assert!(agg.shard_router().is_none(), "no round in flight");
        // Lanes were recovered mid-round, unfinished — and can be reused.
        agg.begin_round(1);
        agg.absorb(0, Update::Mask(vec![0.0; 6]));
        agg.finish_round();
        for (_, spy) in agg.into_shards() {
            assert!(spy.finished, "superseding round completed");
            assert_eq!(spy.absorbed.len(), 2, "one absorb per round attempt");
        }
    }

    #[test]
    fn router_fans_out_from_foreign_threads() {
        let d = 8;
        let mut agg = spy_shards(d, 2);
        agg.begin_round(4);
        let router = agg.shard_router().expect("round in flight");
        std::thread::scope(|scope| {
            for w in 0..2 {
                let router = router.clone();
                scope.spawn(move || {
                    for slot in [w, w + 2] {
                        let v: Vec<f32> = (0..d).map(|i| (slot * 10 + i) as f32).collect();
                        router.route(slot, &Update::Mask(v));
                    }
                });
            }
        });
        drop(router);
        agg.finish_round();
        for (range, spy) in agg.into_shards() {
            assert_eq!(spy.absorbed.len(), 4);
            for (slot, sub) in &spy.absorbed {
                let expect: Vec<f32> = range.clone().map(|i| (slot * 10 + i) as f32).collect();
                assert_eq!(sub, &expect, "slot {slot} range {range:?}");
            }
        }
    }

    #[test]
    fn drop_mid_round_joins_lanes() {
        let mut agg = spy_shards(4, 2);
        agg.begin_round(2);
        agg.absorb(0, Update::Mask(vec![1.0; 4]));
        drop(agg); // must not hang or leak a blocked lane thread
    }
}
