//! **EDEN** (Vargaftik et al. 2022) — communication-efficient distributed
//! mean estimation: randomized Hadamard rotation, coordinate subsampling to
//! the bit budget, 1-bit sign quantization with an unbiased per-vector
//! scale, inverse rotation on the server.
//!
//! Applied to the mask-score delta Δs (App. C.1 baseline configuration).
//! The default 0.7 coordinate fraction reproduces the paper's ≈0.70 bpp
//! EDEN operating point.

use super::{fwht, rand_signs, wire, DecodeCtx, EncodeCtx, Encoded, Family, Update, UpdateCodec};
use crate::util::rng::Xoshiro256pp;
use anyhow::{ensure, Result};

pub struct EdenCodec {
    /// Fraction of rotated coordinates transmitted (1 bit each); the
    /// untransmitted rest decode to zero. Server knows the subset from the
    /// shared seed, so no indexes travel.
    pub fraction: f64,
}

impl Default for EdenCodec {
    fn default() -> Self {
        Self { fraction: 0.7 }
    }
}

fn padded_len(d: usize) -> usize {
    d.next_power_of_two()
}

/// Seeded coordinate subset of size k out of n (shared client/server).
fn subset(n: usize, k: usize, seed: u64) -> Vec<u32> {
    let mut rng = Xoshiro256pp::new(seed ^ 0xedeb_0001);
    let mut idx: Vec<u32> = (0..n as u32).collect();
    for i in 0..k {
        let j = i + rng.below((n - i) as u64) as usize;
        idx.swap(i, j);
    }
    idx.truncate(k);
    idx
}

impl UpdateCodec for EdenCodec {
    fn name(&self) -> &'static str {
        "eden"
    }

    fn family(&self) -> Family {
        Family::Delta
    }

    fn encode(&self, ctx: &EncodeCtx) -> Result<Encoded> {
        let d = ctx.d;
        let n = padded_len(d);
        // Rotate: H · D · Δs
        let signs = rand_signs(n, ctx.seed);
        let mut v = vec![0.0f32; n];
        for i in 0..d {
            v[i] = (ctx.s_k[i] - ctx.s_g[i]) * signs[i];
        }
        fwht(&mut v);

        let k = ((self.fraction * d as f64).round() as usize).clamp(1, n);
        let sel = subset(n, k, ctx.seed);
        // Unbiased 1-bit: scale = E|v| over the selected coords, correcting
        // for the dropped mass by n/k.
        let mut scale = 0.0f64;
        for &i in &sel {
            scale += v[i as usize].abs() as f64;
        }
        scale /= k as f64;
        let scale = (scale * n as f64 / k as f64) as f32;

        let mut bytes = Vec::with_capacity(k / 8 + 16);
        wire::put_u32(&mut bytes, d as u32);
        wire::put_u32(&mut bytes, k as u32);
        wire::put_f32(&mut bytes, scale);
        let mut acc = 0u8;
        for (j, &i) in sel.iter().enumerate() {
            if v[i as usize] >= 0.0 {
                acc |= 1 << (j % 8);
            }
            if j % 8 == 7 {
                bytes.push(acc);
                acc = 0;
            }
        }
        if k % 8 != 0 {
            bytes.push(acc);
        }
        Ok(Encoded { bytes })
    }

    fn decode(&self, bytes: &[u8], ctx: &DecodeCtx) -> Result<Update> {
        let mut r = wire::Reader::new(bytes);
        let d = r.u32()? as usize;
        ensure!(d == ctx.d, "dimension mismatch");
        let k = r.u32()? as usize;
        let n = padded_len(d);
        // The encoder clamps k to [1, n]; a k beyond n in a corrupted record
        // would underflow the shared-subset sampler, so reject it here.
        ensure!(k >= 1 && k <= n, "coordinate count {k} outside [1, {n}]");
        let scale = r.f32()?;
        let packed = r.bytes(k.div_ceil(8))?;
        let sel = subset(n, k, ctx.seed);
        // The encode-side scale already folds the n/k subsampling
        // correction; plant sign·scale and let the inverse rotation spread it.
        let mut v = vec![0.0f32; n];
        for (j, &i) in sel.iter().enumerate() {
            let sign = if packed[j / 8] >> (j % 8) & 1 == 1 {
                1.0
            } else {
                -1.0
            };
            v[i as usize] = sign * scale;
        }
        fwht(&mut v); // orthonormal involution ⇒ inverse
        let signs = rand_signs(n, ctx.seed);
        let delta: Vec<f32> = (0..d).map(|i| v[i] * signs[i]).collect();
        Ok(Update::ScoreDelta(delta))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn ctxs<'a>(
        d: usize,
        s_k: &'a [f32],
        s_g: &'a [f32],
    ) -> (EncodeCtx<'a>, DecodeCtx<'a>) {
        (
            EncodeCtx {
                d,
                theta_k: &[],
                theta_g: &[],
                mask_k: &[],
                mask_g: &[],
                s_k,
                s_g,
                kappa: 1.0,
                seed: 42,
            },
            DecodeCtx {
                d,
                mask_g: &[],
                s_g,
                seed: 42,
            },
        )
    }

    #[test]
    fn bpp_matches_fraction() {
        let d = 50_000;
        let mut rng = Xoshiro256pp::new(1);
        let s_k: Vec<f32> = (0..d).map(|_| rng.next_f32() - 0.5).collect();
        let s_g = vec![0.0f32; d];
        let (ctx, _) = ctxs(d, &s_k, &s_g);
        let enc = EdenCodec::default().encode(&ctx).unwrap();
        let bpp = enc.bpp(d);
        assert!((bpp - 0.7).abs() < 0.05, "bpp={bpp}");
    }

    #[test]
    fn reconstruction_preserves_direction() {
        // 1-bit + rotation is lossy but must correlate strongly with the
        // true delta (that's the whole DME game).
        let d = 16_384;
        let mut rng = Xoshiro256pp::new(2);
        let s_g = vec![0.0f32; d];
        let s_k: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let (ctx, dctx) = ctxs(d, &s_k, &s_g);
        let codec = EdenCodec { fraction: 1.0 };
        let enc = codec.encode(&ctx).unwrap();
        let Update::ScoreDelta(rec) = codec.decode(&enc.bytes, &dctx).unwrap() else {
            panic!()
        };
        let dot: f64 = rec.iter().zip(&s_k).map(|(a, b)| (a * b) as f64).sum();
        let na: f64 = rec.iter().map(|a| (a * a) as f64).sum::<f64>().sqrt();
        let nb: f64 = s_k.iter().map(|a| (a * a) as f64).sum::<f64>().sqrt();
        let cos = dot / (na * nb);
        // sign-only quantization of a rotated gaussian: cos ≈ sqrt(2/π) ≈ 0.80
        assert!(cos > 0.7, "cosine={cos}");
    }

    #[test]
    fn norm_roughly_unbiased() {
        let d = 8_192;
        let mut rng = Xoshiro256pp::new(3);
        let s_g = vec![0.0f32; d];
        let s_k: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let (ctx, dctx) = ctxs(d, &s_k, &s_g);
        let codec = EdenCodec { fraction: 1.0 };
        let enc = codec.encode(&ctx).unwrap();
        let Update::ScoreDelta(rec) = codec.decode(&enc.bytes, &dctx).unwrap() else {
            panic!()
        };
        let n_rec: f64 = rec.iter().map(|a| (a * a) as f64).sum::<f64>().sqrt();
        let n_true: f64 = s_k.iter().map(|a| (a * a) as f64).sum::<f64>().sqrt();
        let ratio = n_rec / n_true;
        assert!(ratio > 0.5 && ratio < 1.5, "norm ratio {ratio}");
    }
}
