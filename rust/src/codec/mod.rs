//! Lossless coding substrates, all from scratch:
//!
//! * [`bitio`] — LSB-first bit streams (DEFLATE's bit order).
//! * [`crc`] — CRC-32 (PNG chunks) and Adler-32 (zlib trailer).
//! * [`deflate`] — RFC 1951 compressor (LZ77 + fixed/dynamic Huffman) and
//!   a full inflater, plus the RFC 1950 zlib container. This is the `Ψ(·)`
//!   lossless step of the paper (§3.2: "lossless image compression
//!   techniques such as DEFLATE").
//! * [`png`] — minimal grayscale-8 PNG encoder/decoder: the `A_{k,t}`
//!   "single grayscale image" that carries the fingerprint array.
//! * [`arith`] — adaptive binary arithmetic coder (Rissanen–Langdon), the
//!   sub-1bpp entropy coder FedPM uses for sparse binary masks.
//! * [`pco`] — pcodec-inspired numeric latent compressor (delta /
//!   double-delta coding, GCD extraction, equal-count quantile bins with
//!   adaptive-bit packing, word-aligned batch decode) for the numeric
//!   sequences the wire path carries — sorted mask-index sets and
//!   quantized score side-info.

pub mod arith;
pub mod bitio;
pub mod crc;
pub mod deflate;
pub mod pco;
pub mod png;
