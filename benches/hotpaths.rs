//! **Tracked hot-path suite** — the kernel-level benchmark baseline behind
//! the batched decode / zero-alloc wire-path work: every batched kernel is
//! timed against its retained scalar oracle *in the same run*, parity is
//! asserted bitwise (a divergence exits non-zero, which the CI `bench-smoke`
//! job relies on), and the results land in `BENCH_hotpaths.json` at the
//! repo root so later PRs can regression-check.
//!
//!     cargo bench --bench hotpaths [-- --smoke] [--iters N] [--warmup N]
//!
//! `--smoke` shrinks the dimension sweep and iteration counts to CI scale.
//! Cases: filter membership kernels, the DeltaMask wire path (scratch
//! encode + pooled decode), the `deltamask-pco` numeric-latent wire path on
//! the same fixture (with the ≥ 20% bytes-on-wire gate vs the PNG+DEFLATE
//! record asserted in-run), the sibling mask codecs `maskrn` / `sparse-rsn`
//! (codecs 10–11) on the same fixture, the sharded `drain_round` (serial vs 4 decode
//! workers, vs 4 decode workers × 4 dimension shards — the `_s4` case —
//! vs the round-resident `DrainPipeline` reusing one crew/view across
//! iterations — the `_s4_resident` case — and vs a placed view with one
//! shard absorbed by a `serve_shard_worker` over a UDS socket — the
//! `_s4_remote` case), matmuls, and tracked
//! PNG/DEFLATE throughputs. The JSON schema and the full bench workflow
//! are documented in `benches/README.md`.

use deltamask::bench::{summarize, time_fn, Table};
use deltamask::codec::{deflate, png};
use deltamask::compress::{
    DecodeCtx, DeltaMaskCodec, DeltaMaskPcoCodec, EncodeCtx, EncodeScratch, MaskRnCodec,
    ScratchPool, SparseRsnCodec, Update, UpdateCodec,
};
use deltamask::filters::{BinaryFuse, BloomFilter, MembershipFilter, XorFilter};
use deltamask::native::linalg;
use deltamask::util::cli::Args;
use deltamask::util::json::Json;
use deltamask::util::rng::Xoshiro256pp;

/// One scalar-vs-batched kernel measurement.
struct Pair {
    name: String,
    scalar_secs: f64,
    batched_secs: f64,
    parity: bool,
}

impl Pair {
    fn speedup(&self) -> f64 {
        if self.batched_secs > 0.0 {
            self.scalar_secs / self.batched_secs
        } else {
            0.0
        }
    }
}

/// Scalar Eq. 5 oracle: per-key `contains` sweep (the pre-batching decode
/// inner loop).
fn scalar_decode<M: MembershipFilter>(f: &M, mask: &mut [f32]) {
    for (i, m) in mask.iter_mut().enumerate() {
        if f.contains(i as u64) {
            *m = 1.0 - *m;
        }
    }
}

fn filter_pair<M: MembershipFilter>(
    name: String,
    f: &M,
    d: usize,
    warmup: usize,
    iters: usize,
) -> Pair {
    let base: Vec<f32> = (0..d).map(|i| (i % 2) as f32).collect();
    let mut scalar_mask = base.clone();
    let scalar_secs = summarize(&time_fn(warmup, iters, || {
        scalar_mask.copy_from_slice(&base);
        scalar_decode(f, &mut scalar_mask);
    }))
    .min;
    let mut batched_mask = base.clone();
    let batched_secs = summarize(&time_fn(warmup, iters, || {
        batched_mask.copy_from_slice(&base);
        f.decode_mask_into(&mut batched_mask);
    }))
    .min;
    // Parity on the final iteration's outputs (both start from `base`).
    scalar_mask.copy_from_slice(&base);
    scalar_decode(f, &mut scalar_mask);
    batched_mask.copy_from_slice(&base);
    f.decode_mask_into(&mut batched_mask);
    Pair {
        name,
        scalar_secs,
        batched_secs,
        parity: scalar_mask == batched_mask,
    }
}

fn main() {
    let args = Args::from_env();
    let smoke = args.flag("smoke");
    let iters = args.usize("iters", if smoke { 2 } else { 7 });
    let warmup = args.usize("warmup", if smoke { 1 } else { 2 });
    let dims: Vec<usize> = if smoke {
        vec![100_000]
    } else {
        vec![100_000, 1_000_000, 10_000_000]
    };

    let mut rng = Xoshiro256pp::new(0x40077a7);
    let mut pairs: Vec<Pair> = Vec::new();

    // -- Filter membership kernels: batched vs the scalar per-key sweep ----
    for &d in &dims {
        let n = (d / 50).max(64);
        let keys: Vec<u64> = (0..n).map(|_| rng.below(d as u64)).collect();
        let bf8 = BinaryFuse::<u8, 4>::build(&keys).expect("bfuse8 build");
        pairs.push(filter_pair(
            format!("bfuse8_decode_d{d}"),
            &bf8,
            d,
            warmup,
            iters,
        ));
    }
    {
        let d = dims[0];
        let n = (d / 50).max(64);
        let keys: Vec<u64> = (0..n).map(|_| rng.below(d as u64)).collect();
        let bf32 = BinaryFuse::<u32, 4>::build(&keys).expect("bfuse32 build");
        pairs.push(filter_pair(format!("bfuse32_decode_d{d}"), &bf32, d, warmup, iters));
        let x8 = XorFilter::<u8>::build(&keys).expect("xor8 build");
        pairs.push(filter_pair(format!("xor8_decode_d{d}"), &x8, d, warmup, iters));
        let bloom = BloomFilter::with_bits_per_entry(&keys, 8.62);
        pairs.push(filter_pair(format!("bloom_decode_d{d}"), &bloom, d, warmup, iters));
    }

    // -- DeltaMask end-to-end wire path: fresh-alloc vs scratch/pool -------
    {
        let d = dims[0];
        let theta_g: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
        let theta_k: Vec<f32> = theta_g
            .iter()
            .map(|&p| (p + 0.1 * (rng.next_f32() - 0.5)).clamp(0.01, 0.99))
            .collect();
        let mask_g: Vec<f32> = theta_g.iter().map(|&p| (p > 0.5) as u32 as f32).collect();
        let mask_k: Vec<f32> = theta_k.iter().map(|&p| (p > 0.5) as u32 as f32).collect();
        let codec = DeltaMaskCodec::default();
        let ctx = EncodeCtx {
            d,
            theta_k: &theta_k,
            theta_g: &theta_g,
            mask_k: &mask_k,
            mask_g: &mask_g,
            s_k: &[],
            s_g: &[],
            kappa: 0.8,
            seed: 7,
        };
        let enc_plain_secs = summarize(&time_fn(warmup, iters, || codec.encode(&ctx).unwrap())).min;
        let mut scratch = EncodeScratch::default();
        let enc_scratch_secs =
            summarize(&time_fn(warmup, iters, || codec.encode_with(&ctx, &mut scratch).unwrap()))
                .min;
        let plain = codec.encode(&ctx).unwrap();
        let reused = codec.encode_with(&ctx, &mut scratch).unwrap();
        pairs.push(Pair {
            name: format!("deltamask_encode_d{d}"),
            scalar_secs: enc_plain_secs,
            batched_secs: enc_scratch_secs,
            parity: plain.bytes == reused.bytes,
        });

        let dctx = DecodeCtx {
            d,
            mask_g: &mask_g,
            s_g: &[],
            seed: 7,
        };
        let dec_plain_secs =
            summarize(&time_fn(warmup, iters, || codec.decode(&plain.bytes, &dctx).unwrap())).min;
        let pool = ScratchPool::new();
        let dec_pool_secs = summarize(&time_fn(warmup, iters, || {
            let u = codec.decode_pooled(&plain.bytes, &dctx, &pool).unwrap();
            if let Update::Mask(m) = u {
                pool.put(m); // close the reclaim cycle like drain_round does
            }
        }))
        .min;
        let Update::Mask(want) = codec.decode(&plain.bytes, &dctx).unwrap() else {
            panic!()
        };
        let Update::Mask(got) = codec.decode_pooled(&plain.bytes, &dctx, &pool).unwrap() else {
            panic!()
        };
        pairs.push(Pair {
            name: format!("deltamask_decode_d{d}"),
            scalar_secs: dec_plain_secs,
            batched_secs: dec_pool_secs,
            parity: want == got,
        });

        // -- deltamask-pco (codec 9): the numeric-latent index stream on the
        // same fixture. Scalar column = fresh-alloc encode / decode; batched
        // column = scratch-reusing encode / pooled decode, like above. The
        // bytes-on-wire acceptance gate (pco record ≥ 20% under the
        // PNG+DEFLATE record) is asserted here so a codec regression fails
        // the bench run, not just shifts a number.
        let pco = DeltaMaskPcoCodec::default();
        let pco_enc_plain_secs =
            summarize(&time_fn(warmup, iters, || pco.encode(&ctx).unwrap())).min;
        let mut pco_scratch = EncodeScratch::default();
        let pco_enc_scratch_secs = summarize(&time_fn(warmup, iters, || {
            pco.encode_with(&ctx, &mut pco_scratch).unwrap()
        }))
        .min;
        let pco_plain = pco.encode(&ctx).unwrap();
        let pco_reused = pco.encode_with(&ctx, &mut pco_scratch).unwrap();
        pairs.push(Pair {
            name: format!("deltamask_pco_encode_d{d}"),
            scalar_secs: pco_enc_plain_secs,
            batched_secs: pco_enc_scratch_secs,
            parity: pco_plain.bytes == pco_reused.bytes,
        });
        assert!(
            pco_plain.bytes.len() * 10 <= plain.bytes.len() * 8,
            "bytes-on-wire gate: deltamask-pco ({}B) must be >= 20% smaller \
             than the PNG+DEFLATE record ({}B) on the tracked d={d} fixture",
            pco_plain.bytes.len(),
            plain.bytes.len()
        );

        let pco_dec_plain_secs = summarize(&time_fn(warmup, iters, || {
            pco.decode(&pco_plain.bytes, &dctx).unwrap()
        }))
        .min;
        let pco_dec_pool_secs = summarize(&time_fn(warmup, iters, || {
            let u = pco.decode_pooled(&pco_plain.bytes, &dctx, &pool).unwrap();
            if let Update::Mask(m) = u {
                pool.put(m);
            }
        }))
        .min;
        let Update::Mask(pco_want) = pco.decode(&pco_plain.bytes, &dctx).unwrap() else {
            panic!()
        };
        let Update::Mask(pco_got) = pco.decode_pooled(&pco_plain.bytes, &dctx, &pool).unwrap()
        else {
            panic!()
        };
        pairs.push(Pair {
            name: format!("deltamask_pco_decode_d{d}"),
            scalar_secs: pco_dec_plain_secs,
            batched_secs: pco_dec_pool_secs,
            parity: pco_want == pco_got,
        });

        // -- maskrn (codec 10) + sparse-rsn (codec 11): the sibling-paper
        // mask codecs on the same fixture. Same column scheme as codec 9:
        // scalar = fresh-alloc encode / decode, batched = scratch-reusing
        // encode / pooled decode, parity bitwise on bytes and masks. These
        // cases (and the ablation rows) are what the CI bench-smoke
        // validator pins, so dropping a sibling from the bench fails CI.
        let mrn = MaskRnCodec::default();
        let rsn = SparseRsnCodec::default();
        for (tag, codec) in [("maskrn", &mrn as &dyn UpdateCodec), ("sparse_rsn", &rsn)] {
            let enc_plain_secs =
                summarize(&time_fn(warmup, iters, || codec.encode(&ctx).unwrap())).min;
            let mut sib_scratch = EncodeScratch::default();
            let enc_scratch_secs = summarize(&time_fn(warmup, iters, || {
                codec.encode_with(&ctx, &mut sib_scratch).unwrap()
            }))
            .min;
            let sib_plain = codec.encode(&ctx).unwrap();
            let sib_reused = codec.encode_with(&ctx, &mut sib_scratch).unwrap();
            pairs.push(Pair {
                name: format!("{tag}_encode_d{d}"),
                scalar_secs: enc_plain_secs,
                batched_secs: enc_scratch_secs,
                parity: sib_plain.bytes == sib_reused.bytes,
            });

            let dec_plain_secs = summarize(&time_fn(warmup, iters, || {
                codec.decode(&sib_plain.bytes, &dctx).unwrap()
            }))
            .min;
            let dec_pool_secs = summarize(&time_fn(warmup, iters, || {
                let u = codec.decode_pooled(&sib_plain.bytes, &dctx, &pool).unwrap();
                if let Update::Mask(m) = u {
                    pool.put(m);
                }
            }))
            .min;
            let Update::Mask(sib_want) = codec.decode(&sib_plain.bytes, &dctx).unwrap() else {
                panic!()
            };
            let Update::Mask(sib_got) =
                codec.decode_pooled(&sib_plain.bytes, &dctx, &pool).unwrap()
            else {
                panic!()
            };
            pairs.push(Pair {
                name: format!("{tag}_decode_d{d}"),
                scalar_secs: dec_plain_secs,
                batched_secs: dec_pool_secs,
                parity: sib_want == sib_got,
            });
        }
    }

    // -- Parallel sharded server decode: drain_round w=1 vs w=4 ------------
    // The ROADMAP's top perf target: the Eq. 5 decode sweep over a round's
    // arrivals, serial on the draining thread vs sharded across 4 decode
    // workers (DrainConfig). Parity is bitwise on the aggregated theta_g.
    {
        use deltamask::coordinator::{
            drain_round, ChannelTransport, DrainConfig, Payload, PipelineMode, RoundEngine,
            WireMessage,
        };
        use deltamask::fl::server::MaskServer;
        use deltamask::model::sample_mask_seeded;

        let d = if smoke { 50_000 } else { 200_000 };
        let k = 8usize;
        let workers = 4usize;
        let theta_g: Vec<f32> = (0..d).map(|_| 0.05 + 0.9 * rng.next_f32()).collect();
        let s_g: Vec<f32> = theta_g
            .iter()
            .map(|&p| {
                let p = p.clamp(1e-6, 1.0 - 1e-6);
                (p / (1.0 - p)).ln()
            })
            .collect();
        let mut engine = RoundEngine::new(0xD3C0, k, 1.0, 0.8, 0.25, 1);
        let plan = engine.plan(0, &theta_g, &s_g);
        let codec = deltamask::compress::by_name("deltamask").unwrap();
        let mut encs = Vec::new();
        for slot in 0..plan.expected() {
            let theta_k: Vec<f32> = theta_g
                .iter()
                .map(|&p| (p + 0.2 * (rng.next_f32() - 0.5)).clamp(0.01, 0.99))
                .collect();
            let mut mask_k = Vec::new();
            sample_mask_seeded(&theta_k, plan.seed, &mut mask_k);
            encs.push(
                codec
                    .encode(&plan.encode_ctx(slot, &theta_k, &mask_k, &[]))
                    .expect("deltamask encode"),
            );
        }
        let pool = ScratchPool::new();
        // One fixture-fill for every drain variant: the serial oracle and
        // the sharded candidates must bench the exact same round.
        let fill_channel = || -> ChannelTransport {
            let (channel, sender) = ChannelTransport::new();
            for (slot, enc) in encs.iter().enumerate() {
                sender
                    .send(WireMessage {
                        round: 0,
                        client_id: plan.participants[slot],
                        slot,
                        payload: Payload::Update(enc.clone()),
                        enc_secs: 0.0,
                        loss: 0.0,
                    })
                    .unwrap();
            }
            drop(sender);
            channel
        };
        let drain = |n_workers: usize| -> Vec<f32> {
            let mut channel = fill_channel();
            let mut server = MaskServer::with_theta0(d, 1.0, 0.85);
            drain_round(
                &mut channel,
                &plan,
                codec.as_ref(),
                &mut server,
                DrainConfig::new(PipelineMode::Streaming, n_workers),
                &pool,
            )
            .expect("drain_round");
            server.theta_g
        };
        let serial_secs = summarize(&time_fn(warmup, iters, || {
            drain(1);
        }))
        .min;
        let sharded_secs = summarize(&time_fn(warmup, iters, || {
            drain(workers);
        }))
        .min;
        let parity = drain(1) == drain(workers);
        pairs.push(Pair {
            name: format!("drain_round_deltamask_d{d}_k{k}_w{workers}"),
            scalar_secs: serial_secs,
            batched_secs: sharded_secs,
            parity,
        });

        // Dimension-sharded aggregation on top of the decode workers: the
        // same round drained into a 4-shard view of the server (each shard
        // its own pseudo-count slice + pool + absorb lane), stitched back
        // after the round. Oracle is the same serial drain; parity is
        // bitwise on the stitched theta_g.
        let shards = 4usize;
        let drain_sharded_agg = |n_workers: usize, n_shards: usize| -> Vec<f32> {
            let mut channel = fill_channel();
            let mut server = MaskServer::with_theta0(d, 1.0, 0.85);
            let mut view = server.shard_view(n_shards);
            drain_round(
                &mut channel,
                &plan,
                codec.as_ref(),
                &mut view,
                DrainConfig::sharded(PipelineMode::Streaming, n_workers, n_shards),
                &pool,
            )
            .expect("sharded drain_round");
            server.adopt_shards(view);
            server.theta_g
        };
        let sharded_agg_secs = summarize(&time_fn(warmup, iters, || {
            drain_sharded_agg(workers, shards);
        }))
        .min;
        let parity = drain(1) == drain_sharded_agg(workers, shards);
        pairs.push(Pair {
            name: format!("drain_round_deltamask_d{d}_k{k}_w{workers}_s{shards}"),
            scalar_secs: serial_secs,
            batched_secs: sharded_agg_secs,
            parity,
        });

        // Round-resident pipeline on the same round: ONE DrainPipeline +
        // ONE resident shard view reused by every timed iteration, so the
        // measurement includes zero thread spawns and (after warm-up) zero
        // pool allocations — the `_s4` − `_s4_resident` gap is what
        // `--persistent-pipeline` buys per round. ρ=1 resets the prior
        // every round, so repeated drains of the same fixture are
        // idempotent on the aggregation state.
        {
            use deltamask::coordinator::DrainPipeline;
            use std::sync::Arc;

            let codec_arc: Arc<dyn UpdateCodec> =
                Arc::from(deltamask::compress::by_name("deltamask").unwrap());
            let plan_arc = Arc::new(plan.clone());
            let pipeline =
                DrainPipeline::new(DrainConfig::sharded(PipelineMode::Streaming, workers, shards));
            let mut resident_server = MaskServer::with_theta0(d, 1.0, 0.85);
            let mut resident_view = resident_server.shard_view(shards);
            let resident_secs = summarize(&time_fn(warmup, iters, || {
                let mut channel = fill_channel();
                pipeline
                    .drain_round(&mut channel, &plan_arc, &codec_arc, &mut resident_view)
                    .expect("resident drain_round");
            }))
            .min;
            resident_server.adopt_shards(resident_view);
            let parity = drain(1) == resident_server.theta_g;
            pairs.push(Pair {
                name: format!("drain_round_deltamask_d{d}_k{k}_w{workers}_s{shards}_resident"),
                scalar_secs: serial_secs,
                batched_secs: resident_secs,
                parity,
            });
        }

        // Multi-host shard fabric on the same round: one of the four
        // dimension shards is absorbed by a `serve_shard_worker` session
        // behind a UDS socket (an in-process stand-in for a remote host),
        // the rest stay on local thread lanes. The pipeline and placed
        // view are round-resident, so the timed iterations measure the
        // per-round wire hop (splits + finish + slice return), not
        // connect or thread-spawn cost. The `_s4_resident` −
        // `_s4_remote` gap is the DMW1 fabric tax for one remote lane.
        // Parity is bitwise on the stitched theta_g vs the serial drain.
        {
            use deltamask::coordinator::{
                serve_shard_worker, ConfigFingerprint, DrainPipeline, Listener, ShardPlacement,
                SocketAddrSpec, SocketConfig,
            };
            use std::sync::Arc;

            let fp = ConfigFingerprint {
                seed: 0xD3C0,
                n_clients: k as u64,
                rounds: 1,
                d: d as u64,
            };
            let scfg = SocketConfig::default();
            let sock = std::env::temp_dir()
                .join(format!("deltamask-bench-remote-{}.sock", std::process::id()));
            let _ = std::fs::remove_file(&sock);
            let listener = Listener::bind(&SocketAddrSpec::Uds(sock.clone()))
                .expect("bind bench shard worker");
            // Lingering worker thread, detached on purpose: it ignores the
            // shutdown sent when the view retires, parks in `accept`, and
            // dies with the process.
            std::thread::spawn(move || serve_shard_worker::<MaskServer>(&listener, scfg, fp, true));

            let placement =
                ShardPlacement::parse(&format!("local,uds:{},local,local", sock.display()))
                    .expect("bench placement");
            let codec_arc: Arc<dyn UpdateCodec> =
                Arc::from(deltamask::compress::by_name("deltamask").unwrap());
            let plan_arc = Arc::new(plan.clone());
            let pipeline =
                DrainPipeline::new(DrainConfig::sharded(PipelineMode::Streaming, workers, shards));
            let mut remote_server = MaskServer::with_theta0(d, 1.0, 0.85);
            let mut remote_view = remote_server
                .shard_view_placed(shards, &placement, fp, scfg)
                .expect("bench remote shard view");
            let remote_secs = summarize(&time_fn(warmup, iters, || {
                let mut channel = fill_channel();
                pipeline
                    .drain_round(&mut channel, &plan_arc, &codec_arc, &mut remote_view)
                    .expect("remote drain_round");
            }))
            .min;
            remote_server.adopt_shards(remote_view);
            let parity = drain(1) == remote_server.theta_g;
            pairs.push(Pair {
                name: format!("drain_round_deltamask_d{d}_k{k}_w{workers}_s{shards}_remote"),
                scalar_secs: serial_secs,
                batched_secs: remote_secs,
                parity,
            });
            let _ = std::fs::remove_file(&sock);
        }
    }

    // -- Matmul kernels: blocked vs the seed's scalar loops ----------------
    {
        let (m, k, n) = if smoke { (16, 96, 96) } else { (64, 384, 384) };
        let a: Vec<f32> = (0..m * k).map(|_| rng.next_f32() - 0.5).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.next_f32() - 0.5).collect();
        let bt: Vec<f32> = (0..n * k).map(|_| rng.next_f32() - 0.5).collect();
        let mut c = vec![0.0f32; m * n];

        // Scalar oracles: the seed's exact loop shapes.
        let scalar_nn = |a: &[f32], b: &[f32], c: &mut [f32]| {
            c.fill(0.0);
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                for (kk, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        crow[j] += av * brow[j];
                    }
                }
            }
        };
        let scalar_bt = |a: &[f32], b: &[f32], c: &mut [f32]| {
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                for j in 0..n {
                    let brow = &b[j * k..(j + 1) * k];
                    let mut acc = 0.0f32;
                    for kk in 0..k {
                        acc += arow[kk] * brow[kk];
                    }
                    c[i * n + j] = acc;
                }
            }
        };

        let s = summarize(&time_fn(warmup, iters, || scalar_nn(&a, &b, &mut c))).min;
        let mut c2 = vec![0.0f32; m * n];
        let f = summarize(&time_fn(warmup, iters, || {
            linalg::matmul_nn(&a, &b, &mut c2, m, k, n)
        }))
        .min;
        scalar_nn(&a, &b, &mut c);
        linalg::matmul_nn(&a, &b, &mut c2, m, k, n);
        pairs.push(Pair {
            name: format!("matmul_nn_{m}x{k}x{n}"),
            scalar_secs: s,
            batched_secs: f,
            parity: c == c2,
        });

        let s = summarize(&time_fn(warmup, iters, || scalar_bt(&a, &bt, &mut c))).min;
        let f = summarize(&time_fn(warmup, iters, || {
            linalg::matmul_bt(&a, &bt, &mut c2, m, k, n)
        }))
        .min;
        scalar_bt(&a, &bt, &mut c);
        linalg::matmul_bt(&a, &bt, &mut c2, m, k, n);
        pairs.push(Pair {
            name: format!("matmul_bt_{m}x{k}x{n}"),
            scalar_secs: s,
            batched_secs: f,
            parity: c == c2,
        });
    }

    // -- Tracked throughput (no scalar counterpart in-tree): PNG + DEFLATE -
    let mut tracked: Vec<(String, f64)> = Vec::new();
    {
        let payload_len = if smoke { 65_536 } else { 262_144 };
        let payload: Vec<u8> = (0..payload_len)
            .map(|_| {
                let u = rng.next_f32();
                (-(1.0 - u).ln() * 8.0) as u8
            })
            .collect();
        let img = png::GrayImage::from_payload(&payload);
        let encoded = png::encode(&img);
        let t = summarize(&time_fn(warmup, iters, || png::encode(&img))).min;
        tracked.push((format!("png_encode_{payload_len}B"), t));
        let t = summarize(&time_fn(warmup, iters, || png::decode(&encoded).unwrap())).min;
        tracked.push((format!("png_decode_{payload_len}B"), t));
        let z = deflate::zlib_compress(&payload);
        let t = summarize(&time_fn(warmup, iters, || deflate::zlib_compress(&payload))).min;
        tracked.push((format!("deflate_{payload_len}B"), t));
        let t =
            summarize(&time_fn(warmup, iters, || deflate::zlib_decompress(&z).unwrap())).min;
        tracked.push((format!("inflate_{payload_len}B"), t));
        // Fast-level match finder (4-byte hash, early-exit / capped-lazy
        // heuristics): tracked alongside the baseline emitter so the
        // `deflate_fast_*` − `deflate_*` gap is the measured speedup, and
        // roundtripped through the SAME inflate to pin stream validity.
        let zf = deflate::zlib_compress_fast(&payload);
        let t = summarize(&time_fn(warmup, iters, || deflate::zlib_compress_fast(&payload))).min;
        tracked.push((format!("deflate_fast_{payload_len}B"), t));
        assert_eq!(
            deflate::zlib_decompress(&zf).unwrap(),
            payload,
            "deflate_fast roundtrip parity"
        );
        assert_eq!(
            deflate::zlib_decompress(&z).unwrap(),
            payload,
            "deflate roundtrip parity"
        );
        assert_eq!(
            png::decode(&encoded).unwrap().payload(payload.len()),
            &payload[..],
            "png roundtrip parity"
        );
    }

    // -- Report + parity gate ---------------------------------------------
    let mut table = Table::new(
        "Hot-path kernels: batched vs scalar (min over iters)",
        &["kernel", "scalar s", "batched s", "speedup", "parity"],
    );
    for p in &pairs {
        table.row(vec![
            p.name.clone(),
            format!("{:.6}", p.scalar_secs),
            format!("{:.6}", p.batched_secs),
            format!("{:.2}x", p.speedup()),
            if p.parity { "ok".into() } else { "DIVERGED".into() },
        ]);
    }
    table.print();
    for (name, secs) in &tracked {
        println!("  tracked {name}: {secs:.6}s");
    }

    let mut root = Json::obj();
    root.set("schema", Json::from_str_("deltamask-hotpaths-v1"))
        .set(
            "provenance",
            Json::from_str_("cargo bench --bench hotpaths (see benches/README.md to regenerate)"),
        )
        .set("smoke", Json::Bool(smoke))
        .set("iters", Json::Num(iters as f64))
        .set("warmup", Json::Num(warmup as f64));
    root.set(
        "kernels",
        Json::Arr(
            pairs
                .iter()
                .map(|p| {
                    let mut o = Json::obj();
                    o.set("name", Json::from_str_(&p.name))
                        .set("scalar_secs", Json::Num(p.scalar_secs))
                        .set("batched_secs", Json::Num(p.batched_secs))
                        .set("speedup", Json::Num(p.speedup()))
                        .set("parity", Json::Bool(p.parity));
                    o
                })
                .collect(),
        ),
    );
    root.set(
        "tracked",
        Json::Arr(
            tracked
                .iter()
                .map(|(name, secs)| {
                    let mut o = Json::obj();
                    o.set("name", Json::from_str_(name)).set("secs", Json::Num(*secs));
                    o
                })
                .collect(),
        ),
    );
    std::fs::write("BENCH_hotpaths.json", root.to_string_pretty())
        .expect("write BENCH_hotpaths.json");
    println!("[saved BENCH_hotpaths.json]");

    let diverged: Vec<&str> = pairs
        .iter()
        .filter(|p| !p.parity)
        .map(|p| p.name.as_str())
        .collect();
    assert!(
        diverged.is_empty(),
        "kernel parity oracles diverged: {diverged:?}"
    );
}
