//! `Backend` implementation over the AOT-compiled XLA graphs — the
//! production execution path (L2 JAX model + L1 Pallas kernels, via PJRT).
//!
//! Frozen tensors (backbone blocks, post-LP head) are uploaded once and kept
//! device-resident; only the mutable state round-trips per step.

use super::executor::{Executor, GraphHandle};
use crate::model::backend::{Backend, FtState, LpState, ModelParams};
use crate::model::MaskState;
use anyhow::{ensure, Context, Result};
use std::sync::{Arc, Mutex};

pub struct XlaBackend {
    exec: Arc<Executor>,
    train: GraphHandle,
    eval: GraphHandle,
    lp: GraphHandle,
    ft: GraphHandle,
    cache: Mutex<DeviceCache>,
}

struct DeviceCache {
    w_blocks: Option<xla::PjRtBuffer>,
    head_w: Option<xla::PjRtBuffer>,
    head_b: Option<xla::PjRtBuffer>,
    head_version: u64,
}

// Safety: same rationale as Executor — buffers are only touched under the
// mutex or by PJRT's thread-safe execute path.
unsafe impl Send for XlaBackend {}
unsafe impl Sync for XlaBackend {}

impl XlaBackend {
    pub fn new(exec: Arc<Executor>, arch: &str, c: usize) -> Result<Self> {
        Ok(Self {
            train: exec.graph(arch, c, "train")?,
            eval: exec.graph(arch, c, "eval")?,
            lp: exec.graph(arch, c, "lp")?,
            ft: exec.graph(arch, c, "ft")?,
            exec,
            cache: Mutex::new(DeviceCache {
                w_blocks: None,
                head_w: None,
                head_b: None,
                head_version: u64::MAX,
            }),
        })
    }

    /// Ensure device copies of the frozen tensors are current; runs under
    /// the cache lock. Returns clones of the underlying buffer handles is
    /// not possible, so callers re-enter the lock per use.
    fn refresh(&self, params: &ModelParams) -> Result<()> {
        let mut cache = self.cache.lock().unwrap();
        let cfg = params.cfg;
        if cache.w_blocks.is_none() {
            cache.w_blocks = Some(
                self.exec
                    .upload(&params.w_blocks, &[cfg.l, cfg.f, cfg.f])
                    .context("upload w_blocks")?,
            );
        }
        if cache.head_version != params.head_version {
            cache.head_w = Some(self.exec.upload(&params.head_w, &[cfg.c, cfg.f])?);
            cache.head_b = Some(self.exec.upload(&params.head_b, &[cfg.c])?);
            cache.head_version = params.head_version;
        }
        Ok(())
    }
}

impl Backend for XlaBackend {
    fn train_step(
        &self,
        params: &ModelParams,
        state: &mut MaskState,
        x: &[f32],
        y_onehot: &[f32],
        u: &[f32],
    ) -> Result<f32> {
        let cfg = params.cfg;
        let d = cfg.d();
        ensure!(state.s.len() == d && u.len() == d);
        ensure!(x.len() == cfg.b * cfg.f && y_onehot.len() == cfg.b * cfg.c);
        self.refresh(params)?;
        state.step += 1;
        let t = [state.step as f32];

        let s_b = self.exec.upload(&state.s, &[d])?;
        let mt_b = self.exec.upload(&state.mt, &[d])?;
        let vt_b = self.exec.upload(&state.vt, &[d])?;
        let t_b = self.exec.upload(&t, &[])?;
        let x_b = self.exec.upload(x, &[cfg.b, cfg.f])?;
        let y_b = self.exec.upload(y_onehot, &[cfg.b, cfg.c])?;
        let u_b = self.exec.upload(u, &[d])?;

        let cache = self.cache.lock().unwrap();
        let outs = self.train.execute(&[
            &s_b,
            &mt_b,
            &vt_b,
            &t_b,
            cache.w_blocks.as_ref().unwrap(),
            cache.head_w.as_ref().unwrap(),
            cache.head_b.as_ref().unwrap(),
            &x_b,
            &y_b,
            &u_b,
        ])?;
        drop(cache);
        let mut it = outs.into_iter();
        state.s = it.next().unwrap();
        state.mt = it.next().unwrap();
        state.vt = it.next().unwrap();
        let loss = it.next().unwrap()[0];
        Ok(loss)
    }

    fn eval_logits(&self, params: &ModelParams, mask: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        let cfg = params.cfg;
        ensure!(mask.len() == cfg.d() && x.len() == cfg.b * cfg.f);
        self.refresh(params)?;
        let m_b = self.exec.upload(mask, &[cfg.d()])?;
        let x_b = self.exec.upload(x, &[cfg.b, cfg.f])?;
        let cache = self.cache.lock().unwrap();
        let outs = self.eval.execute(&[
            &m_b,
            cache.w_blocks.as_ref().unwrap(),
            cache.head_w.as_ref().unwrap(),
            cache.head_b.as_ref().unwrap(),
            &x_b,
        ])?;
        Ok(outs.into_iter().next().unwrap())
    }

    fn lp_step(
        &self,
        params: &ModelParams,
        state: &mut LpState,
        x: &[f32],
        y_onehot: &[f32],
    ) -> Result<f32> {
        let cfg = params.cfg;
        self.refresh(params)?;
        state.step += 1;
        let t = [state.step as f32];
        let hw = self.exec.upload(&state.head_w, &[cfg.c, cfg.f])?;
        let hb = self.exec.upload(&state.head_b, &[cfg.c])?;
        let m_hw = self.exec.upload(&state.m_hw, &[cfg.c, cfg.f])?;
        let v_hw = self.exec.upload(&state.v_hw, &[cfg.c, cfg.f])?;
        let m_hb = self.exec.upload(&state.m_hb, &[cfg.c])?;
        let v_hb = self.exec.upload(&state.v_hb, &[cfg.c])?;
        let t_b = self.exec.upload(&t, &[])?;
        let x_b = self.exec.upload(x, &[cfg.b, cfg.f])?;
        let y_b = self.exec.upload(y_onehot, &[cfg.b, cfg.c])?;
        let cache = self.cache.lock().unwrap();
        let outs = self.lp.execute(&[
            &hw,
            &hb,
            &m_hw,
            &v_hw,
            &m_hb,
            &v_hb,
            &t_b,
            cache.w_blocks.as_ref().unwrap(),
            &x_b,
            &y_b,
        ])?;
        drop(cache);
        let mut it = outs.into_iter();
        state.head_w = it.next().unwrap();
        state.head_b = it.next().unwrap();
        state.m_hw = it.next().unwrap();
        state.v_hw = it.next().unwrap();
        state.m_hb = it.next().unwrap();
        state.v_hb = it.next().unwrap();
        Ok(it.next().unwrap()[0])
    }

    fn ft_step(
        &self,
        params: &ModelParams,
        state: &mut FtState,
        x: &[f32],
        y_onehot: &[f32],
    ) -> Result<f32> {
        let cfg = params.cfg;
        state.step += 1;
        let t = [state.step as f32];
        let shapes_wb = [cfg.l, cfg.f, cfg.f];
        let wb = self.exec.upload(&state.w_blocks, &shapes_wb)?;
        let hw = self.exec.upload(&state.head_w, &[cfg.c, cfg.f])?;
        let hb = self.exec.upload(&state.head_b, &[cfg.c])?;
        let m_wb = self.exec.upload(&state.m_wb, &shapes_wb)?;
        let v_wb = self.exec.upload(&state.v_wb, &shapes_wb)?;
        let m_hw = self.exec.upload(&state.m_hw, &[cfg.c, cfg.f])?;
        let v_hw = self.exec.upload(&state.v_hw, &[cfg.c, cfg.f])?;
        let m_hb = self.exec.upload(&state.m_hb, &[cfg.c])?;
        let v_hb = self.exec.upload(&state.v_hb, &[cfg.c])?;
        let t_b = self.exec.upload(&t, &[])?;
        let x_b = self.exec.upload(x, &[cfg.b, cfg.f])?;
        let y_b = self.exec.upload(y_onehot, &[cfg.b, cfg.c])?;
        let outs = self.ft.execute(&[
            &wb, &hw, &hb, &m_wb, &v_wb, &m_hw, &v_hw, &m_hb, &v_hb, &t_b, &x_b, &y_b,
        ])?;
        let mut it = outs.into_iter();
        state.w_blocks = it.next().unwrap();
        state.head_w = it.next().unwrap();
        state.head_b = it.next().unwrap();
        state.m_wb = it.next().unwrap();
        state.v_wb = it.next().unwrap();
        state.m_hw = it.next().unwrap();
        state.v_hw = it.next().unwrap();
        state.m_hb = it.next().unwrap();
        state.v_hb = it.next().unwrap();
        Ok(it.next().unwrap()[0])
    }

    fn ft_eval_logits(
        &self,
        params: &ModelParams,
        state: &FtState,
        x: &[f32],
    ) -> Result<Vec<f32>> {
        let cfg = params.cfg;
        // Evaluate the FT weights through the eval graph with mask ≡ 1 by
        // temporarily treating FT weights as the frozen set (no cache).
        let ones = vec![1.0f32; cfg.d()];
        let m_b = self.exec.upload(&ones, &[cfg.d()])?;
        let wb = self.exec.upload(&state.w_blocks, &[cfg.l, cfg.f, cfg.f])?;
        let hw = self.exec.upload(&state.head_w, &[cfg.c, cfg.f])?;
        let hb = self.exec.upload(&state.head_b, &[cfg.c])?;
        let x_b = self.exec.upload(x, &[cfg.b, cfg.f])?;
        let outs = self.eval.execute(&[&m_b, &wb, &hw, &hb, &x_b])?;
        Ok(outs.into_iter().next().unwrap())
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}
