//! `artifacts/manifest.json` — the shape contract between `aot.py` and the
//! rust runtime. Parsed with our own JSON parser (no serde offline).

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Clone, Debug)]
pub struct GraphSpec {
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct ComboSpec {
    pub arch: String,
    pub f: usize,
    pub c: usize,
    pub b: usize,
    pub l: usize,
    pub d: usize,
    pub graphs: BTreeMap<String, GraphSpec>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub combos: Vec<ComboSpec>,
    /// dataset name -> class count (the paper's 8 datasets)
    pub datasets: BTreeMap<String, usize>,
}

fn tensor_specs(v: &Json) -> Result<Vec<TensorSpec>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("expected array of tensor specs"))?
        .iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("tensor missing name"))?
                    .to_string(),
                shape: t
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("tensor missing shape"))?
                    .iter()
                    .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<_>>()?,
                dtype: t
                    .get("dtype")
                    .and_then(Json::as_str)
                    .unwrap_or("f32")
                    .to_string(),
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let root = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let mut combos = Vec::new();
        for combo in root
            .get("combos")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing combos"))?
        {
            let get_usize = |k: &str| -> Result<usize> {
                combo
                    .get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("combo missing {k}"))
            };
            let mut graphs = BTreeMap::new();
            if let Some(Json::Obj(gmap)) = combo.get("graphs") {
                for (gname, g) in gmap {
                    let file = g
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("graph missing file"))?;
                    graphs.insert(
                        gname.clone(),
                        GraphSpec {
                            file: dir.join(file),
                            inputs: tensor_specs(
                                g.get("inputs").ok_or_else(|| anyhow!("no inputs"))?,
                            )?,
                            outputs: tensor_specs(
                                g.get("outputs").ok_or_else(|| anyhow!("no outputs"))?,
                            )?,
                        },
                    );
                }
            }
            combos.push(ComboSpec {
                arch: combo
                    .get("arch")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("combo missing arch"))?
                    .to_string(),
                f: get_usize("F")?,
                c: get_usize("C")?,
                b: get_usize("B")?,
                l: get_usize("L")?,
                d: get_usize("d")?,
                graphs,
            });
        }
        let mut datasets = BTreeMap::new();
        if let Some(Json::Obj(m)) = root.get("datasets") {
            for (k, v) in m {
                datasets.insert(
                    k.clone(),
                    v.as_usize().ok_or_else(|| anyhow!("bad dataset class count"))?,
                );
            }
        }
        if combos.is_empty() {
            bail!("manifest has no combos");
        }
        Ok(Self {
            dir: dir.to_path_buf(),
            combos,
            datasets,
        })
    }

    pub fn find(&self, arch: &str, c: usize) -> Option<&ComboSpec> {
        self.combos.iter().find(|k| k.arch == arch && k.c == c)
    }
}

impl ComboSpec {
    pub fn graph(&self, name: &str) -> Result<&GraphSpec> {
        self.graphs
            .get(name)
            .ok_or_else(|| anyhow!("combo {}/{} has no graph '{name}'", self.arch, self.c))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_synthetic_manifest() {
        let dir = std::env::temp_dir().join(format!("dm_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let text = r#"{
 "version": 1,
 "datasets": {"cifar10": 10},
 "archs": {"test": 32},
 "combos": [
  {"arch": "test", "F": 32, "C": 10, "B": 8, "L": 5, "d": 5120,
   "graphs": {"eval": {"file": "test_c10_eval.hlo.txt",
     "inputs": [{"name": "mask", "shape": [5120], "dtype": "f32"}],
     "outputs": [{"name": "logits", "shape": [8, 10], "dtype": "f32"}]}}}
 ]}"#;
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.combos.len(), 1);
        let c = m.find("test", 10).unwrap();
        assert_eq!(c.d, 5120);
        let g = c.graph("eval").unwrap();
        assert_eq!(g.inputs[0].elements(), 5120);
        assert_eq!(g.outputs[0].shape, vec![8, 10]);
        assert!(c.graph("train").is_err());
        assert_eq!(m.datasets["cifar10"], 10);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_errors() {
        let dir = std::env::temp_dir().join("dm_no_manifest_xyz");
        assert!(Manifest::load(&dir).is_err());
    }
}
