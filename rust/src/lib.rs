//! # DeltaMask
//!
//! Reproduction of *"Federated Fine-Tuning of Foundation Models via
//! Probabilistic Masking"* (Tsouvalas, Asano, Saeed — 2023) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the federated system, split into two layers:
//!   the [`coordinator`] subsystem (transport-agnostic round engine:
//!   `RoundPlan`/`RoundEngine` for sampling, κ scheduling and shared-seed
//!   mask derivation; a `Transport` carrying encoded updates with wire
//!   accounting; a work-stealing `ClientPool`; the batch-vs-streaming
//!   `PipelineMode`; a `DrainConfig`-sharded server decode pool wired to
//!   `--decode-workers`; the dimension-sharded
//!   `coordinator::ShardedAggregator` absorb lanes wired to
//!   `--agg-shards`; and the round-resident `coordinator::DrainPipeline`
//!   wired to `--persistent-pipeline`, which keeps workers, lanes and
//!   buffer pools alive across rounds), and the [`fl`] experiment layer
//!   on top of it
//!   (state ownership, the streaming Bayesian [`fl::server::MaskServer`],
//!   baselines, metrics). Updates are decoded and absorbed per-arrival —
//!   the server never materializes a round's O(K·d) update set — plus the
//!   DeltaMask codec (binary fuse filters → grayscale PNG) and every
//!   baseline codec the paper compares against, under [`compress`].
//! * **L2 (`python/compile/model.py`)** — the masked-model compute graph
//!   (fwd/bwd + Adam on mask scores), AOT-lowered once to HLO text.
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels for the masked
//!   matmul hot-spot, lowered into the same HLO.
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! pre-compiled artifacts through the PJRT C API and executes them natively
//! (behind the `xla` cargo feature; without it a stub reports the missing
//! integration and the pure-rust [`native`] backend drives everything).
//!
//! ## Documentation map
//!
//! * **`docs/ARCHITECTURE.md`** — the contributor-facing layer map
//!   (filters → codec → compress → coordinator → fl), the round lifecycle
//!   (plan → encode → wire → decode → shard-split absorb → finish/stitch),
//!   where the sharded decode workers and the dimension-sharded absorb
//!   lanes sit, and the wire-format invariants each layer guarantees.
//!   Read it before touching the coordinator or a codec.
//! * **`docs/SCALING.md`** — the operator's guide to the server scaling
//!   knobs (`--pipeline`, `--decode-workers`, `--agg-shards`): what each
//!   parallelizes, how they compose, which traffic regime needs which,
//!   and how to tune them from `RoundMetrics`/`BENCH_hotpaths.json`.
//! * **`README.md`** — build/run/test quickstart and the CLI tour.
//! * **`benches/README.md`** — the tracked hot-path suite, the
//!   `BENCH_hotpaths.json` schema (`deltamask-hotpaths-v1`), how to
//!   regenerate it, and how CI's `bench-smoke` job gates kernel parity.
//!
//! ## Hot-path posture (summary)
//!
//! The encode→wire→decode hot path runs on **batched monomorphic kernels**
//! (blocked filter membership via `MembershipFilter::{contains_batch,
//! decode_mask_into}`, word-at-a-time bit I/O, fused-pair literal emission,
//! unrolled matmuls) with **reusable scratch** (`compress::EncodeScratch`
//! per client session, a `compress::ScratchPool` of decode buffers cycling
//! through `coordinator::drain_round` ↔ `Aggregator::reclaim_buffer`), so
//! steady-state rounds allocate nothing on the wire path — and the server
//! decode sweep shards across a worker pool while the absorb sweep shards
//! across the dimension axis ([`coordinator::DrainConfig`], CLI
//! `--decode-workers N` / `--agg-shards S`), with the whole crew
//! optionally round-resident ([`coordinator::DrainPipeline`], CLI
//! `--persistent-pipeline`: spawn once, park between rounds, pool
//! hit/miss counters proving the zero-alloc steady state). Every batched
//! or sharded variant is parity-locked to a retained scalar/serial oracle:
//! it changes *how* work is scheduled or queried, never what is encoded —
//! all 11 codecs stay bitwise-identical on the wire and in the aggregate.
//! `benches/hotpaths.rs` asserts this on every run.

pub mod bench;
pub mod codec;
pub mod compress;
pub mod coordinator;
pub mod filters;
pub mod fl;
pub mod hash;
pub mod model;
pub mod native;
pub mod runtime;
pub mod util;
