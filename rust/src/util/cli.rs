//! Tiny CLI argument parser (`--key value`, `--flag`, positional args).
//! The offline vendor set has no `clap`; this covers everything the
//! coordinator binary, examples and benches need.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless next token is another option or
                    // there is no next token -> boolean flag.
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.options.insert(name.to_string(), v);
                        }
                        _ => out.flags.push(name.to_string()),
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self.options.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} must be an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} must be an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} must be a number, got '{v}'")))
            .unwrap_or(default)
    }

    /// Validated enumeration option: `--name <one of allowed>`, panicking
    /// with the permitted values on anything else (used by e.g.
    /// `--pipeline {batch,streaming}` and `--backend {native,xla}`).
    pub fn choice<'a>(&'a self, name: &str, allowed: &[&'a str], default: &'a str) -> &'a str {
        let v = self.get_or(name, default);
        if !allowed.contains(&v) {
            panic!("--{name} must be one of {allowed:?}, got '{v}'");
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn basic_forms() {
        let a = parse("train --rounds 30 --full --kappa=0.8 cifar100 --out x.json");
        assert_eq!(a.positional, vec!["train", "cifar100"]);
        assert_eq!(a.usize("rounds", 0), 30);
        assert!(a.flag("full"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.f64("kappa", 0.0), 0.8);
        assert_eq!(a.get("out"), Some("x.json"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("--verbose");
        assert!(a.flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.usize("n", 7), 7);
        assert_eq!(a.f64("x", 1.5), 1.5);
        assert_eq!(a.get_or("name", "dflt"), "dflt");
    }

    #[test]
    fn choice_accepts_allowed_values() {
        let a = parse("--pipeline batch");
        assert_eq!(a.choice("pipeline", &["batch", "streaming"], "streaming"), "batch");
        let b = parse("");
        assert_eq!(b.choice("pipeline", &["batch", "streaming"], "streaming"), "streaming");
    }

    #[test]
    #[should_panic(expected = "--pipeline must be one of")]
    fn choice_rejects_unknown_values() {
        let a = parse("--pipeline turbo");
        a.choice("pipeline", &["batch", "streaming"], "streaming");
    }

    #[test]
    fn negative_number_value() {
        // `--lr -0.1` — the value does not start with `--` so it binds.
        let a = parse("--lr -0.1");
        assert_eq!(a.f64("lr", 0.0), -0.1);
    }
}
