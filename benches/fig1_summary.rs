//! **Figure 1** — the headline scatter: accuracy vs bits-per-parameter for
//! DeltaMask and every communication-efficient baseline, averaged over the
//! dataset roster (ViT-B/32 sim).
//!
//!     cargo bench --bench fig1_summary [-- --full]

use deltamask::bench::{bench_datasets, BenchScale, Table};
use deltamask::fl::run_experiment;
use deltamask::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let scale = BenchScale::from_args(&args);
    let datasets = bench_datasets(&args);
    let methods = [
        "fine_tuning",
        "fedmask",
        "qsgd",
        "drive",
        "eden",
        "fedcode",
        "deepreduce",
        "fedpm",
        "deltamask",
    ];

    let mut table = Table::new(
        "Figure 1 (avg over datasets): accuracy vs bpp",
        &["method", "avg acc", "avg bpp", "acc drop vs FT"],
    );
    let mut ft_acc = 0.0;
    let mut rows = Vec::new();
    for method in methods {
        let mut accs = Vec::new();
        let mut bpps = Vec::new();
        for dataset in &datasets {
            let cfg = scale.config(dataset, method);
            let res = run_experiment(&cfg)?;
            accs.push(res.final_accuracy());
            bpps.push(res.avg_bpp());
        }
        let acc = deltamask::util::stats::mean(&accs);
        let bpp = deltamask::util::stats::mean(&bpps);
        eprintln!("  {method}: acc={acc:.4} bpp={bpp:.4}");
        if method == "fine_tuning" {
            ft_acc = acc;
        }
        rows.push((method, acc, bpp));
    }
    for (method, acc, bpp) in rows {
        table.row(vec![
            method.to_string(),
            format!("{:.4}", acc),
            format!("{:.4}", bpp),
            format!("{:+.4}", acc - ft_acc),
        ]);
    }
    table.print();
    table.save("fig1_summary");
    println!("\nshape check: deltamask should sit at the lowest bpp among methods");
    println!("within a few points of fedpm/fine-tuning accuracy (paper Fig. 1).");
    Ok(())
}
