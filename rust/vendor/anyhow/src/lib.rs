//! Minimal, offline, API-compatible subset of the `anyhow` crate.
//!
//! The repository builds with no network access, so instead of pulling the
//! real crate from a registry this shim provides exactly the surface the
//! codebase uses:
//!
//! * [`Error`] — an opaque boxed error with a source chain,
//! * [`Result<T>`] with the `Error` default type parameter,
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros,
//! * the [`Context`] extension trait (`.context(..)` / `.with_context(..)`).
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`; that is what makes the blanket
//! `impl<E: std::error::Error> From<E> for Error` coherent, so `?` works on
//! any standard error type.

use std::error::Error as StdError;
use std::fmt;

/// A type-erased error with an optional chain of sources.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

impl Error {
    /// Wrap a concrete error value.
    pub fn new<E>(error: E) -> Self
    where
        E: StdError + Send + Sync + 'static,
    {
        Self {
            inner: Box::new(error),
        }
    }

    /// Create an error from a printable message.
    pub fn msg<M>(message: M) -> Self
    where
        M: fmt::Display,
    {
        Self {
            inner: Box::new(MessageError(message.to_string())),
        }
    }

    /// Attach a higher-level context message, keeping `self` as the source.
    pub fn context<C>(self, context: C) -> Self
    where
        C: fmt::Display,
    {
        Self {
            inner: Box::new(ContextError {
                context: context.to_string(),
                source: self.inner,
            }),
        }
    }

    /// Iterate the source chain, outermost first.
    pub fn chain(&self) -> Chain<'_> {
        Chain {
            next: Some(self.inner.as_ref()),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.inner, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        let mut source = self.inner.source();
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(cause) = source {
            write!(f, "\n    {cause}")?;
            source = cause.source();
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(error: E) -> Self {
        Self::new(error)
    }
}

/// Iterator over an error's source chain (outermost first).
pub struct Chain<'a> {
    next: Option<&'a (dyn StdError + 'static)>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a (dyn StdError + 'static);

    fn next(&mut self) -> Option<Self::Item> {
        let cur = self.next?;
        self.next = cur.source();
        Some(cur)
    }
}

struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

struct ContextError {
    context: String,
    source: Box<dyn StdError + Send + Sync + 'static>,
}

impl fmt::Display for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.context)
    }
}

impl fmt::Debug for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.context, self.source)
    }
}

impl StdError for ContextError {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        Some(self.source.as_ref())
    }
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to results
/// and options, mirroring the real crate.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: StdError + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn needs_q(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn macros_and_conversion() {
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        assert!(needs_q(true).is_ok());
        assert_eq!(needs_q(false).unwrap_err().to_string(), "flag was false");

        // `?` conversion from a std error type.
        fn io_fail() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/real/path/xyz")?;
            Ok(s)
        }
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_chains() {
        let base: Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::Other,
            "disk on fire",
        ));
        let err = base.context("loading manifest").unwrap_err();
        assert_eq!(err.to_string(), "loading manifest");
        let chain: Vec<String> = err.chain().map(|c| c.to_string()).collect();
        assert_eq!(chain.len(), 2);
        assert!(chain[1].contains("disk on fire"));
        let dbg = format!("{err:?}");
        assert!(dbg.contains("Caused by"));

        let opt: Option<u8> = None;
        assert!(opt.context("missing").is_err());
    }
}
