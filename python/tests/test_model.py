"""L2 correctness: the train/eval/lp/ft graphs behave like training steps —
losses decrease, Adam matches a hand-rolled reference, shapes line up with
the manifest specs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

CFG = M.ModelConfig(name="test", F=32, C=10, B=8)


def init_state(rng, cfg=CFG):
    d = cfg.d
    kaiming = np.sqrt(2.0 / cfg.F)
    wb = rng.standard_normal((cfg.L, cfg.F, cfg.F)).astype(np.float32) * kaiming
    hw = rng.standard_normal((cfg.C, cfg.F)).astype(np.float32) * 0.05
    hb = np.zeros(cfg.C, np.float32)
    s = np.zeros(d, np.float32)  # θ = 0.5 at init, like FedPM
    return wb, hw, hb, s


def make_batch(rng, cfg=CFG):
    """Linearly-separable-ish synthetic batch so training can reduce loss."""
    y = rng.integers(0, cfg.C, size=cfg.B)
    protos = rng.standard_normal((cfg.C, cfg.F)).astype(np.float32)
    x = protos[y] + 0.1 * rng.standard_normal((cfg.B, cfg.F)).astype(np.float32)
    y1h = np.eye(cfg.C, dtype=np.float32)[y]
    return x, y1h


def test_train_step_decreases_loss():
    rng = np.random.default_rng(0)
    wb, hw, hb, s = init_state(rng)
    x, y1h = make_batch(rng)
    train = jax.jit(M.make_train_step(CFG))
    d = CFG.d
    mt = np.zeros(d, np.float32)
    vt = np.zeros(d, np.float32)
    losses = []
    s, mt, vt = jnp.asarray(s), jnp.asarray(mt), jnp.asarray(vt)
    for t in range(1, 31):
        u = jnp.asarray(rng.uniform(size=d).astype(np.float32))
        s, mt, vt, loss = train(
            s, mt, vt, jnp.float32(t), wb, hw, hb, x, y1h, u
        )
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses[:3] + losses[-3:]


def test_train_step_only_moves_scores():
    """Frozen weights: w_blocks / head are inputs, never outputs."""
    spec = M.graph_specs(CFG)["train"]
    out_names = [n for n, _ in spec["outputs"]]
    assert out_names == ["s", "mt", "vt", "loss"]


def test_adam_update_matches_manual():
    rng = np.random.default_rng(3)
    p = rng.standard_normal(16).astype(np.float32)
    g = rng.standard_normal(16).astype(np.float32)
    mt = np.zeros(16, np.float32)
    vt = np.zeros(16, np.float32)
    p2, mt2, vt2 = M.adam_update(
        jnp.asarray(p), jnp.asarray(g), jnp.asarray(mt), jnp.asarray(vt),
        jnp.float32(1.0), 0.1,
    )
    # Manual Adam, t=1.
    mt_ref = 0.1 * g
    vt_ref = 0.001 * g * g
    mhat = mt_ref / (1 - 0.9)
    vhat = vt_ref / (1 - 0.999)
    p_ref = p - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(p2, p_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(mt2, mt_ref, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(vt2, vt_ref, rtol=1e-6, atol=1e-7)


def test_eval_matches_reference_forward():
    rng = np.random.default_rng(1)
    wb, hw, hb, _ = init_state(rng)
    x, _ = make_batch(rng)
    mask = (rng.uniform(size=CFG.d) < 0.5).astype(np.float32)
    ev = jax.jit(M.make_eval_step(CFG))
    got = ev(jnp.asarray(mask), wb, hw, hb, x)
    want = ref.forward_ref(
        jnp.asarray(x), wb, mask.reshape(CFG.L, CFG.F, CFG.F), hw, hb
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_lp_step_trains_head_only():
    rng = np.random.default_rng(2)
    wb, hw, hb, _ = init_state(rng)
    x, y1h = make_batch(rng)
    lp = jax.jit(M.make_lp_step(CFG))
    zeros_hw = np.zeros_like(hw)
    zeros_hb = np.zeros_like(hb)
    state = (jnp.asarray(hw), jnp.asarray(hb), jnp.asarray(zeros_hw),
             jnp.asarray(zeros_hw), jnp.asarray(zeros_hb), jnp.asarray(zeros_hb))
    losses = []
    for t in range(1, 41):
        *state, loss = lp(*state, jnp.float32(t), wb, x, y1h)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5, (losses[0], losses[-1])


def test_ft_step_trains_weights():
    rng = np.random.default_rng(4)
    wb, hw, hb, _ = init_state(rng)
    x, y1h = make_batch(rng)
    ft = jax.jit(M.make_ft_step(CFG))
    z = lambda a: jnp.zeros_like(jnp.asarray(a))
    state = (jnp.asarray(wb), jnp.asarray(hw), jnp.asarray(hb),
             z(wb), z(wb), z(hw), z(hw), z(hb), z(hb))
    losses = []
    for t in range(1, 41):
        *state, loss = ft(*state, jnp.float32(t), x, y1h)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, (losses[0], losses[-1])
    # Weights actually moved.
    assert not np.allclose(np.asarray(state[0]), wb)


def test_graph_specs_shapes_consistent():
    specs = M.graph_specs(CFG)
    assert set(specs) == {"train", "eval", "lp", "ft"}
    for graph, spec in specs.items():
        for name, shape in spec["inputs"] + spec["outputs"]:
            assert isinstance(name, str) and isinstance(shape, tuple), (graph, name)
    assert specs["train"]["inputs"][0] == ("s", (CFG.d,))
    assert specs["eval"]["outputs"][0] == ("logits", (CFG.B, CFG.C))


def test_deterministic_given_same_uniforms():
    """Shared-seed reproducibility: same u ⇒ identical step output."""
    rng = np.random.default_rng(5)
    wb, hw, hb, s = init_state(rng)
    x, y1h = make_batch(rng)
    u = rng.uniform(size=CFG.d).astype(np.float32)
    train = jax.jit(M.make_train_step(CFG))
    args = (jnp.asarray(s), jnp.zeros(CFG.d), jnp.zeros(CFG.d),
            jnp.float32(1.0), wb, hw, hb, x, y1h, jnp.asarray(u))
    out1 = train(*args)
    out2 = train(*args)
    for a, b in zip(out1, out2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
