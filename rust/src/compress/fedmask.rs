//! **FedMask** (Li et al. 2021a) — deterministic threshold masks at 1 bpp.
//!
//! Per App. C.1 the paper runs FedMask without its personalization pruning
//! phase: the client mask is the hard threshold m = 1[θ ≥ τ] and the raw
//! bit vector is transmitted (packed, no entropy coding) — the canonical
//! 1.0 bpp row of Tables 2/3.

use super::{wire, DecodeCtx, EncodeCtx, Encoded, Family, Update, UpdateCodec};
use anyhow::{ensure, Result};

pub struct FedMaskCodec {
    pub tau: f32,
}

impl Default for FedMaskCodec {
    fn default() -> Self {
        Self { tau: 0.5 }
    }
}

impl UpdateCodec for FedMaskCodec {
    fn name(&self) -> &'static str {
        "fedmask"
    }

    fn family(&self) -> Family {
        Family::Mask
    }

    /// FedMask keeps personalized local scores across rounds (its masks are
    /// deterministic thresholds of locally-trained scores).
    fn resync_scores(&self) -> bool {
        false
    }

    fn encode(&self, ctx: &EncodeCtx) -> Result<Encoded> {
        let mut bytes = Vec::with_capacity(ctx.d / 8 + 8);
        wire::put_u32(&mut bytes, ctx.d as u32);
        let mut acc = 0u8;
        for (i, &p) in ctx.theta_k.iter().enumerate() {
            if p >= self.tau {
                acc |= 1 << (i % 8);
            }
            if i % 8 == 7 {
                bytes.push(acc);
                acc = 0;
            }
        }
        if ctx.d % 8 != 0 {
            bytes.push(acc);
        }
        Ok(Encoded { bytes })
    }

    fn decode(&self, bytes: &[u8], ctx: &DecodeCtx) -> Result<Update> {
        let mut r = wire::Reader::new(bytes);
        let d = r.u32()? as usize;
        ensure!(d == ctx.d, "dimension mismatch");
        let packed = r.bytes(d.div_ceil(8))?;
        let mask = (0..d)
            .map(|i| {
                if packed[i / 8] >> (i % 8) & 1 == 1 {
                    1.0
                } else {
                    0.0
                }
            })
            .collect();
        Ok(Update::Mask(mask))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn exactly_one_bpp_and_threshold_semantics() {
        let d = 8_000;
        let mut rng = Xoshiro256pp::new(1);
        let theta: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
        let ctx = EncodeCtx {
            d,
            theta_k: &theta,
            theta_g: &theta,
            mask_k: &[],
            mask_g: &[],
            s_k: &[],
            s_g: &[],
            kappa: 1.0,
            seed: 0,
        };
        let codec = FedMaskCodec::default();
        let enc = codec.encode(&ctx).unwrap();
        // d/8 bytes + 4-byte header.
        assert_eq!(enc.bytes.len(), d / 8 + 4);
        let dctx = DecodeCtx {
            d,
            mask_g: &[],
            s_g: &[],
            seed: 0,
        };
        let Update::Mask(m) = codec.decode(&enc.bytes, &dctx).unwrap() else {
            panic!()
        };
        for (i, &p) in theta.iter().enumerate() {
            assert_eq!(m[i] > 0.5, p >= 0.5, "index {i}");
        }
    }

    #[test]
    fn odd_length_mask() {
        let d = 13;
        let theta = vec![0.9f32; d];
        let ctx = EncodeCtx {
            d,
            theta_k: &theta,
            theta_g: &theta,
            mask_k: &[],
            mask_g: &[],
            s_k: &[],
            s_g: &[],
            kappa: 1.0,
            seed: 0,
        };
        let codec = FedMaskCodec::default();
        let enc = codec.encode(&ctx).unwrap();
        let dctx = DecodeCtx {
            d,
            mask_g: &[],
            s_g: &[],
            seed: 0,
        };
        let Update::Mask(m) = codec.decode(&enc.bytes, &dctx).unwrap() else {
            panic!()
        };
        assert_eq!(m, vec![1.0; d]);
    }
}
