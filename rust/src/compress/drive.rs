//! **DRIVE** (Vargaftik et al. 2021) — "one-bit distributed mean
//! estimation": randomized Hadamard rotation, then the *full* sign vector
//! plus a single optimal scale (⟨v, sign(v)⟩ / d), inverse-rotated on the
//! server. Exactly 1 bit/coordinate + O(1) floats ⇒ the ≈1.0 bpp row of
//! Fig. 1.

use super::{fwht, rand_signs, wire, DecodeCtx, EncodeCtx, Encoded, Family, Update, UpdateCodec};
use anyhow::{ensure, Result};

pub struct DriveCodec;

impl UpdateCodec for DriveCodec {
    fn name(&self) -> &'static str {
        "drive"
    }

    fn family(&self) -> Family {
        Family::Delta
    }

    fn encode(&self, ctx: &EncodeCtx) -> Result<Encoded> {
        let d = ctx.d;
        let n = d.next_power_of_two();
        let signs = rand_signs(n, ctx.seed);
        let mut v = vec![0.0f32; n];
        for i in 0..d {
            v[i] = (ctx.s_k[i] - ctx.s_g[i]) * signs[i];
        }
        fwht(&mut v);
        // DRIVE's optimal scale minimizes ‖v − scale·sign(v)‖²:
        // scale = Σ|v_i| / n.
        let scale = (v.iter().map(|x| x.abs() as f64).sum::<f64>() / n as f64) as f32;
        let mut bytes = Vec::with_capacity(n / 8 + 12);
        wire::put_u32(&mut bytes, d as u32);
        wire::put_f32(&mut bytes, scale);
        let mut acc = 0u8;
        for (j, &x) in v.iter().enumerate() {
            if x >= 0.0 {
                acc |= 1 << (j % 8);
            }
            if j % 8 == 7 {
                bytes.push(acc);
                acc = 0;
            }
        }
        if n % 8 != 0 {
            bytes.push(acc);
        }
        Ok(Encoded { bytes })
    }

    fn decode(&self, bytes: &[u8], ctx: &DecodeCtx) -> Result<Update> {
        let mut r = wire::Reader::new(bytes);
        let d = r.u32()? as usize;
        ensure!(d == ctx.d, "dimension mismatch");
        let scale = r.f32()?;
        let n = d.next_power_of_two();
        let packed = r.bytes(n.div_ceil(8))?;
        let mut v = vec![0.0f32; n];
        for (j, x) in v.iter_mut().enumerate() {
            *x = if packed[j / 8] >> (j % 8) & 1 == 1 {
                scale
            } else {
                -scale
            };
        }
        fwht(&mut v);
        let signs = rand_signs(n, ctx.seed);
        Ok(Update::ScoreDelta(
            (0..d).map(|i| v[i] * signs[i]).collect(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn one_bpp_and_high_cosine() {
        let d = 10_000;
        let mut rng = Xoshiro256pp::new(5);
        let s_g = vec![0.0f32; d];
        let s_k: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let ctx = EncodeCtx {
            d,
            theta_k: &[],
            theta_g: &[],
            mask_k: &[],
            mask_g: &[],
            s_k: &s_k,
            s_g: &s_g,
            kappa: 1.0,
            seed: 7,
        };
        let enc = DriveCodec.encode(&ctx).unwrap();
        // next_pow2(10000)=16384 bits / 10000 params ≈ 1.64 bpp worst case
        // padding; on pow2 dims it is exactly ~1.0.
        assert!(enc.bpp(d) < 1.7, "bpp={}", enc.bpp(d));
        let dctx = DecodeCtx {
            d,
            mask_g: &[],
            s_g: &s_g,
            seed: 7,
        };
        let Update::ScoreDelta(rec) = DriveCodec.decode(&enc.bytes, &dctx).unwrap() else {
            panic!()
        };
        let dot: f64 = rec.iter().zip(&s_k).map(|(a, b)| (a * b) as f64).sum();
        let na = rec.iter().map(|a| (a * a) as f64).sum::<f64>().sqrt();
        let nb = s_k.iter().map(|a| (a * a) as f64).sum::<f64>().sqrt();
        assert!(dot / (na * nb) > 0.7, "cos={}", dot / (na * nb));
    }
}
