//! Update-compression codecs: DeltaMask (the paper's contribution) and every
//! baseline in the evaluation (§4: FedPM, FedMask, DeepReduce, EDEN, DRIVE,
//! QSGD, FedCode).
//!
//! Two update families exist:
//! * **Mask family** — clients transmit (a compressed form of) their sampled
//!   binary mask `m^{k,t}`; the server Bayesian-aggregates (Alg. 2).
//!   DeltaMask (filter + PNG payload, or the `deltamask-pco` numeric-latent
//!   index stream), FedPM, FedMask, DeepReduce.
//! * **Delta family** — clients transmit a compressed score update
//!   `Δs = s^{k,t} − s^{g,t-1}`; the server FedAvg-aggregates scores.
//!   EDEN, DRIVE, QSGD, FedCode (classic gradient compression applied to
//!   the mask-score vector, per App. C.1's baseline configuration).
//!
//! The mask family also hosts the two sibling-paper codecs: `maskrn`
//! (codec 10 — Masked Random Noise: Δ′ flips gated by a seed-derived
//! frozen noise dictionary) and `sparse-rsn` (codec 11 — Regularized
//! Sparse Random Networks: an absolute λ-penalized 1-bit supermask with
//! polarity-optimized wire cost). Both reuse the codec-9 pco index-stream
//! wire stage and compose with every drain shape through the same
//! `encode_with`/`decode_pooled`/`range_decoder` surface.
//!
//! Every codec serializes *all* side information (seeds, scales, layout
//! params) into its byte payload so the measured `wire_bits = 8·|bytes|`
//! is an honest uplink count — the bpp figures in the benches come straight
//! from these bytes.

pub mod deepreduce;
pub mod deltamask;
pub mod deltamask_pco;
pub mod drive;
pub mod eden;
pub mod fedcode;
pub mod fedmask;
pub mod fedpm;
pub mod maskrn;
pub mod qsgd;
pub mod sparse_rsn;

pub use deltamask::{DeltaMaskCodec, FilterKind, PayloadBackend, Ranking};
pub use deltamask_pco::DeltaMaskPcoCodec;
pub use maskrn::MaskRnCodec;
pub use sparse_rsn::SparseRsnCodec;

use crate::util::rng::Xoshiro256pp;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    /// Binary mask updates → Bayesian aggregation.
    Mask,
    /// Score-delta updates → FedAvg on scores.
    Delta,
}

/// Client-side view handed to `encode`.
pub struct EncodeCtx<'a> {
    pub d: usize,
    /// Client posterior mask probabilities θ^{k,t}.
    pub theta_k: &'a [f32],
    /// Broadcast global probabilities θ^{g,t-1}.
    pub theta_g: &'a [f32],
    /// Client's sampled binary mask m^{k,t} (0.0/1.0).
    pub mask_k: &'a [f32],
    /// Shared-seed global binary mask m^{g,t-1} (identical on server).
    pub mask_g: &'a [f32],
    /// Client scores s^{k,t} (delta family).
    pub s_k: &'a [f32],
    /// Broadcast scores s^{g,t-1} (delta family).
    pub s_g: &'a [f32],
    /// Current top-κ fraction (cosine schedule).
    pub kappa: f64,
    /// Deterministic per-(round, client) seed for codec-internal randomness
    /// (rotations, quantization dithers). Known to the server.
    pub seed: u64,
}

/// Server-side view handed to `decode`.
///
/// The borrows are **round-start snapshots** (normally the coordinator's
/// `RoundPlan`), never live server state: streaming aggregation mutates the
/// server's posterior while later updates are still being decoded, and
/// decoders must see the same m^{g,t-1} / s^{g,t-1} the clients encoded
/// against. `RoundPlan::decode_ctx` builds these correctly.
pub struct DecodeCtx<'a> {
    pub d: usize,
    pub mask_g: &'a [f32],
    pub s_g: &'a [f32],
    pub seed: u64,
}

/// A reconstructed client update.
#[derive(Clone, Debug)]
pub enum Update {
    /// Reconstructed binary mask m̂^{k,t} (0.0/1.0, may contain filter
    /// false-positive flips — that noise is part of the experiment).
    Mask(Vec<f32>),
    /// Reconstructed score delta Δŝ.
    ScoreDelta(Vec<f32>),
}

impl Update {
    /// Which aggregation rule this update feeds (Bayesian vs FedAvg).
    pub fn family(&self) -> Family {
        match self {
            Update::Mask(_) => Family::Mask,
            Update::ScoreDelta(_) => Family::Delta,
        }
    }

    /// Reconstructed vector length (the mask dimensionality d).
    pub fn len(&self) -> usize {
        match self {
            Update::Mask(v) | Update::ScoreDelta(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Consume the update, returning the underlying reconstruction buffer
    /// — for recycling into a [`ScratchPool`] once the contents have been
    /// folded into (or copied out for) the aggregation state.
    pub fn into_vec(self) -> Vec<f32> {
        match self {
            Update::Mask(v) | Update::ScoreDelta(v) => v,
        }
    }
}

/// Encoded uplink message.
#[derive(Clone, Debug)]
pub struct Encoded {
    pub bytes: Vec<u8>,
}

impl Encoded {
    pub fn wire_bits(&self) -> u64 {
        self.bytes.len() as u64 * 8
    }

    pub fn bpp(&self, d: usize) -> f64 {
        self.wire_bits() as f64 / d as f64
    }
}

/// Reusable client-side encode scratch: the Δ scan, its KL scores, the
/// quickselect index array and the truncated key set live in buffers that
/// persist across rounds (inside `ClientSession`), so steady-state
/// encodes never re-allocate them.
#[derive(Debug, Default)]
pub struct EncodeScratch {
    /// Mask-difference index set Δ.
    pub delta: Vec<u32>,
    /// KL scores aligned with `delta` (KL ranking only).
    pub scores: Vec<f32>,
    /// Quickselect index scratch for the top-κ ranking
    /// (`util::top_k_indices_into`; KL ranking only).
    pub rank: Vec<u32>,
    /// Ranked, truncated key set Δ′ handed to the filter builder.
    pub keys: Vec<u64>,
}

/// Lease accounting for a [`ScratchPool`]: how many `take_copy` calls were
/// served from the free list (`hits`) versus forced to allocate a fresh
/// buffer (`misses`). Counters are cumulative over the pool's lifetime;
/// sample them before and after a round and subtract
/// ([`PoolStats::delta_since`]) for per-round accounting. A pool that
/// outlives its rounds (the round-resident drain pipeline) shows `misses`
/// frozen after warm-up — that is the observable form of the cross-round
/// zero-allocation property.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Leases served from the free list (no allocation).
    pub hits: u64,
    /// Leases that allocated because the free list was dry.
    pub misses: u64,
}

impl PoolStats {
    /// Counter deltas since an earlier sample of the same pool.
    pub fn delta_since(self, baseline: PoolStats) -> PoolStats {
        PoolStats {
            hits: self.hits - baseline.hits,
            misses: self.misses - baseline.misses,
        }
    }

    /// Component-wise sum (for folding lane pools into one figure).
    pub fn merged(self, other: PoolStats) -> PoolStats {
        PoolStats {
            hits: self.hits + other.hits,
            misses: self.misses + other.misses,
        }
    }
}

/// Free-list of reusable `d`-length f32 update buffers for the server-side
/// decode path. `drain_round` pops a spent buffer for each decode and the
/// aggregator pushes buffers back once their contents are folded into the
/// global state, so steady-state rounds decode with zero allocation.
///
/// The pool is `Sync` (internally locked), so one instance outlives a round
/// and is shared by every decode worker when the drain is sharded
/// (`DrainConfig::workers > 1`): each worker leases its output buffer with
/// [`ScratchPool::take_copy`] and the absorb stage returns spent buffers
/// with [`ScratchPool::put`]. The lock is held only for the push/pop, never
/// across a decode. Every lease is counted ([`ScratchPool::stats`]), so the
/// zero-alloc steady state is observable, not just asserted.
///
/// ```
/// use deltamask::compress::ScratchPool;
/// let pool = ScratchPool::new();
/// let buf = pool.take_copy(&[1.0, 2.0]); // pool is dry: allocates
/// assert_eq!(buf, vec![1.0, 2.0]);
/// assert_eq!(pool.stats().misses, 1);
/// pool.put(buf); // spent: back on the free list
/// assert_eq!(pool.spares(), 1);
/// let again = pool.take_copy(&[7.0]); // reuses the spare, no allocation
/// assert_eq!(again, vec![7.0]);
/// assert_eq!(pool.spares(), 0);
/// assert_eq!((pool.stats().hits, pool.stats().misses), (1, 1));
/// ```
#[derive(Debug, Default)]
pub struct ScratchPool {
    bufs: std::sync::Mutex<Vec<Vec<f32>>>,
    hits: std::sync::atomic::AtomicU64,
    misses: std::sync::atomic::AtomicU64,
}

impl ScratchPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pop a spare buffer filled with a copy of `init` (the m^{g,t-1}
    /// baseline for mask decodes), allocating only when the pool is dry.
    pub fn take_copy(&self, init: &[f32]) -> Vec<f32> {
        use std::sync::atomic::Ordering;
        let spare = self.bufs.lock().unwrap().pop();
        let mut buf = match spare {
            Some(buf) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                buf
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Vec::new()
            }
        };
        buf.clear();
        buf.extend_from_slice(init);
        buf
    }

    /// Return a spent buffer for reuse.
    pub fn put(&self, buf: Vec<f32>) {
        // Keep the free list small: a round needs at most a handful of
        // in-flight buffers (one per decode worker plus the bounded
        // decode→absorb hand-off window).
        let mut bufs = self.bufs.lock().unwrap();
        if bufs.len() < 64 {
            bufs.push(buf);
        }
    }

    /// Number of idle buffers (test/bench observability).
    pub fn spares(&self) -> usize {
        self.bufs.lock().unwrap().len()
    }

    /// Cumulative lease counters (see [`PoolStats`]).
    pub fn stats(&self) -> PoolStats {
        use std::sync::atomic::Ordering;
        PoolStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }
}

/// A parsed-and-validated mask-family record that can reconstruct any
/// contiguous sub-range of the Eq. 5 decode independently.
///
/// Parsing (header validation, PNG/DEFLATE unpacking, filter rebuild)
/// happens **once** in [`UpdateCodec::range_decoder`]; the membership sweep
/// then runs per `d`-range, so a dimension-sharded drain can hand each
/// shard's range to its own absorb lane without ever materializing the
/// full `d`-length reconstruction — one huge record parallelizes end to
/// end (the decode sweep, not just the absorb). Range decoding is exact:
/// concatenating `decode_range` over a tiling of `0..d` is bitwise
/// identical to the full decode (membership — including the filter's
/// false positives — is a per-index property).
pub trait MaskRangeDecoder: Send + Sync {
    /// Apply the record's mask flips to `mask`, which holds the m^{g,t-1}
    /// baseline for coordinates `range` (`mask.len() == range.len()`);
    /// member index `i` flips `mask[i - range.start]`.
    fn decode_range(&self, range: std::ops::Range<usize>, mask: &mut [f32]);
}

pub trait UpdateCodec: Send + Sync {
    fn name(&self) -> &'static str;
    fn family(&self) -> Family;
    /// Whether clients re-seed their local scores from the broadcast θ_g
    /// each round (stochastic-mask methods) or keep personalized local
    /// scores (FedMask's thresholded-mask regime).
    fn resync_scores(&self) -> bool {
        true
    }
    fn encode(&self, ctx: &EncodeCtx) -> anyhow::Result<Encoded>;
    fn decode(&self, bytes: &[u8], ctx: &DecodeCtx) -> anyhow::Result<Update>;

    /// Encode reusing the caller's scratch buffers. The default ignores the
    /// scratch and allocates per call; hot-path codecs (DeltaMask) override.
    /// Must produce bytes identical to `encode`.
    ///
    /// ```
    /// use deltamask::compress::{self, EncodeCtx, EncodeScratch};
    /// let d = 64;
    /// let theta_g = vec![0.4f32; d];
    /// let theta_k = vec![0.6f32; d];
    /// let mask_g = vec![0.0f32; d];
    /// let mask_k: Vec<f32> = (0..d).map(|i| (i % 2) as f32).collect();
    /// let ctx = EncodeCtx {
    ///     d, theta_k: &theta_k, theta_g: &theta_g, mask_k: &mask_k,
    ///     mask_g: &mask_g, s_k: &[], s_g: &[], kappa: 0.8, seed: 1,
    /// };
    /// let codec = compress::by_name("deltamask").unwrap();
    /// let mut scratch = EncodeScratch::default();
    /// let fresh = codec.encode(&ctx).unwrap();
    /// let reused = codec.encode_with(&ctx, &mut scratch).unwrap();
    /// assert_eq!(fresh.bytes, reused.bytes); // scratch never changes the wire
    /// ```
    fn encode_with(&self, ctx: &EncodeCtx, scratch: &mut EncodeScratch) -> anyhow::Result<Encoded> {
        let _ = scratch;
        self.encode(ctx)
    }

    /// Decode drawing the output buffer from `pool` instead of allocating.
    /// The default falls back to `decode`; mask-family codecs with dense
    /// reconstruction override. Must produce an update identical to
    /// `decode` — the batched kernels change *how* membership is queried,
    /// never what is decoded.
    ///
    /// ```
    /// use deltamask::compress::{self, DecodeCtx, EncodeCtx, ScratchPool, Update};
    /// let d = 64;
    /// let theta_g = vec![0.4f32; d];
    /// let theta_k = vec![0.6f32; d];
    /// let mask_g = vec![0.0f32; d];
    /// let mask_k: Vec<f32> = (0..d).map(|i| (i % 2) as f32).collect();
    /// let codec = compress::by_name("deltamask").unwrap();
    /// let enc = codec.encode(&EncodeCtx {
    ///     d, theta_k: &theta_k, theta_g: &theta_g, mask_k: &mask_k,
    ///     mask_g: &mask_g, s_k: &[], s_g: &[], kappa: 0.8, seed: 1,
    /// }).unwrap();
    ///
    /// let dctx = DecodeCtx { d, mask_g: &mask_g, s_g: &[], seed: 1 };
    /// let pool = ScratchPool::new();
    /// let plain = codec.decode(&enc.bytes, &dctx).unwrap();
    /// let pooled = codec.decode_pooled(&enc.bytes, &dctx, &pool).unwrap();
    /// match (plain, pooled) {
    ///     (Update::Mask(a), Update::Mask(b)) => {
    ///         assert_eq!(a, b); // pooling never changes what is decoded
    ///         pool.put(b);      // spent buffer back to the free list
    ///     }
    ///     _ => unreachable!(),
    /// }
    /// assert_eq!(pool.spares(), 1);
    /// ```
    fn decode_pooled(
        &self,
        bytes: &[u8],
        ctx: &DecodeCtx,
        pool: &ScratchPool,
    ) -> anyhow::Result<Update> {
        let _ = pool;
        self.decode(bytes, ctx)
    }

    /// Parse and validate a record **once** into a [`MaskRangeDecoder`]
    /// whose membership sweep can then run per `d`-range (the
    /// dimension-sharded drain decodes each shard's range directly into
    /// that shard's absorb lane). Returns `Ok(None)` when the codec cannot
    /// restrict its reconstruction to a range — delta-family transforms
    /// (FWHT rotations, global dequantization) and dense mask bitmaps need
    /// the whole vector — in which case callers fall back to
    /// [`UpdateCodec::decode_pooled`] plus a split at shard boundaries.
    /// Filter-backed mask codecs (DeltaMask, DeepReduce) override.
    ///
    /// Contract: for any tiling of `0..d`, initializing each tile from
    /// `ctx.mask_g` and applying `decode_range` must reproduce the full
    /// [`UpdateCodec::decode`] output bitwise, and parse/validation errors
    /// must match `decode`'s (malformed records are rejected here, before
    /// any range is swept).
    fn range_decoder(
        &self,
        bytes: &[u8],
        ctx: &DecodeCtx,
    ) -> anyhow::Result<Option<Box<dyn MaskRangeDecoder>>> {
        let _ = (bytes, ctx);
        Ok(None)
    }
}

/// Construct a codec by its CLI/bench name.
pub fn by_name(name: &str) -> Option<Box<dyn UpdateCodec>> {
    Some(match name {
        "deltamask" => Box::new(DeltaMaskCodec::default()),
        "deltamask-bfuse16" => Box::new(DeltaMaskCodec::with_filter(FilterKind::BFuse16)),
        "deltamask-bfuse32" => Box::new(DeltaMaskCodec::with_filter(FilterKind::BFuse32)),
        "deltamask-xor8" => Box::new(DeltaMaskCodec::with_filter(FilterKind::Xor8)),
        "deltamask-xor16" => Box::new(DeltaMaskCodec::with_filter(FilterKind::Xor16)),
        "deltamask-xor32" => Box::new(DeltaMaskCodec::with_filter(FilterKind::Xor32)),
        "deltamask-random" => Box::new(DeltaMaskCodec::with_ranking(Ranking::Random)),
        "deltamask-pco" => Box::new(DeltaMaskPcoCodec::default()),
        "maskrn" => Box::new(MaskRnCodec::default()),
        "sparse-rsn" => Box::new(SparseRsnCodec::default()),
        "fedpm" => Box::new(fedpm::FedPmCodec),
        "fedmask" => Box::new(fedmask::FedMaskCodec::default()),
        "deepreduce" => Box::new(deepreduce::DeepReduceCodec::default()),
        "eden" => Box::new(eden::EdenCodec::default()),
        "drive" => Box::new(drive::DriveCodec),
        "qsgd" => Box::new(qsgd::QsgdCodec::default()),
        "fedcode" => Box::new(fedcode::FedCodeCodec::default()),
        _ => return None,
    })
}

/// All codec names used across the benches.
pub fn all_names() -> &'static [&'static str] {
    &[
        "deltamask",
        "deltamask-pco",
        "maskrn",
        "sparse-rsn",
        "fedpm",
        "fedmask",
        "deepreduce",
        "eden",
        "drive",
        "qsgd",
        "fedcode",
    ]
}

// ---------------------------------------------------------------------------
// Shared helpers
// ---------------------------------------------------------------------------

/// Little-endian record writer/readers for codec headers.
pub(crate) mod wire {
    pub fn put_u32(out: &mut Vec<u8>, v: u32) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(out: &mut Vec<u8>, v: u64) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f32(out: &mut Vec<u8>, v: f32) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    pub struct Reader<'a> {
        pub data: &'a [u8],
        pub pos: usize,
    }

    impl<'a> Reader<'a> {
        pub fn new(data: &'a [u8]) -> Self {
            Self { data, pos: 0 }
        }

        pub fn u32(&mut self) -> anyhow::Result<u32> {
            anyhow::ensure!(self.pos + 4 <= self.data.len(), "truncated u32");
            let v = u32::from_le_bytes(self.data[self.pos..self.pos + 4].try_into()?);
            self.pos += 4;
            Ok(v)
        }

        pub fn u64(&mut self) -> anyhow::Result<u64> {
            anyhow::ensure!(self.pos + 8 <= self.data.len(), "truncated u64");
            let v = u64::from_le_bytes(self.data[self.pos..self.pos + 8].try_into()?);
            self.pos += 8;
            Ok(v)
        }

        pub fn f32(&mut self) -> anyhow::Result<f32> {
            Ok(f32::from_bits(self.u32()?))
        }

        pub fn bytes(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
            anyhow::ensure!(self.pos + n <= self.data.len(), "truncated bytes");
            let s = &self.data[self.pos..self.pos + n];
            self.pos += n;
            Ok(s)
        }
    }
}

/// In-place fast Walsh–Hadamard transform (length must be a power of two),
/// orthonormalized. Used by the EDEN/DRIVE randomized rotation.
pub(crate) fn fwht(v: &mut [f32]) {
    let n = v.len();
    debug_assert!(n.is_power_of_two());
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let x = v[j];
                let y = v[j + h];
                v[j] = x + y;
                v[j + h] = x - y;
            }
            i += 2 * h;
        }
        h *= 2;
    }
    let scale = 1.0 / (n as f32).sqrt();
    for x in v.iter_mut() {
        *x *= scale;
    }
}

const SIGN_SEED_SALT: u64 = 0x51_6e_c0_de_5e_ed_00_01;

/// Seeded random sign diagonal for the randomized Hadamard rotation.
pub(crate) fn rand_signs(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256pp::new(seed ^ SIGN_SEED_SALT);
    (0..n)
        .map(|_| if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fwht_is_orthonormal_involution() {
        let mut rng = Xoshiro256pp::new(1);
        let n = 256;
        let orig: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
        let mut v = orig.clone();
        fwht(&mut v);
        // Norm preserved.
        let n0: f32 = orig.iter().map(|x| x * x).sum();
        let n1: f32 = v.iter().map(|x| x * x).sum();
        assert!((n0 - n1).abs() < 1e-3, "{n0} vs {n1}");
        // H(H(x)) = x for orthonormal H.
        fwht(&mut v);
        for (a, b) in v.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn by_name_covers_all() {
        for name in all_names() {
            assert!(by_name(name).is_some(), "{name}");
        }
        assert!(by_name("nonsense").is_none());
    }
}
