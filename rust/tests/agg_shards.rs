//! Dimension-sharded aggregator determinism suite: draining a round into
//! a [`ShardedAggregator`] view of the server (`DrainConfig::shards > 1`)
//! and stitching the shard slices back must be **bitwise identical** to
//! the single-lane reference path — for every codec (both update
//! families), both pipeline modes, shard counts {1, 2, 3, 8} and both
//! decode-stage shapes (inline and worker-routed), under adversarial
//! arrival orders. A malformed record under sharded absorb must abort
//! the round cleanly: decode workers joined, every shard lane joined,
//! the view reusable.
//!
//! Lane placement: every shard view here is built through [`shard_view`],
//! which honours the ambient `DELTAMASK_SHARD_PLACE` spec — the CI
//! `remote-shards` knob-matrix entry points this whole suite at standing
//! `deltamask shard-worker --linger` processes over UDS (mixed
//! local/remote lanes), re-proving every bitwise property across the
//! process boundary. Unset means all-local in-process lanes.

use deltamask::compress::{self, Encoded, ScratchPool, UpdateCodec};
use deltamask::coordinator::{
    drain_round, serve_shard_worker, shard_bounds, Aggregator, ChannelTransport,
    ConfigFingerprint, DrainConfig, DrainPipeline, Listener, Payload, PipelineMode, RoundEngine,
    RoundPlan, ShardPlacement, ShardedAggregator, SocketAddrSpec, SocketConfig, WireMessage,
};
use deltamask::fl::server::MaskServer;
use deltamask::model::sample_mask_seeded;
use deltamask::util::rng::Xoshiro256pp;
use std::sync::Arc;

/// The fingerprint the CI `remote-shards` standing workers are launched
/// with (`shard-worker --arch test --clients 8 --rounds 4 --seed 42`):
/// arch `test` ⇒ d = 5·32² = 5120, which bounds every slice range this
/// suite ships, and 8 clients covers every per-round expected count used
/// here. The in-thread worker test below reuses it so one constant pins
/// both harnesses.
fn ci_fingerprint() -> ConfigFingerprint {
    ConfigFingerprint {
        seed: 42,
        n_clients: 8,
        rounds: 4,
        d: 5120,
    }
}

/// The ambient `DELTAMASK_SHARD_PLACE` sites padded with `local` (or
/// truncated) to the view's **resolved** lane count, so the fixed
/// two-worker CI spec composes with every shard count and every `d` this
/// suite sweeps (shard counts clamp to `d`). `None` when unset/empty.
fn placed_spec(d: usize, shards: usize) -> Option<String> {
    let spec = deltamask::fl::shard_place_from_env();
    let sites: Vec<&str> = spec
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .collect();
    if sites.is_empty() {
        return None;
    }
    let lanes = shard_bounds(d, shards).len();
    let padded: Vec<&str> = (0..lanes)
        .map(|i| sites.get(i).copied().unwrap_or("local"))
        .collect();
    Some(padded.join(","))
}

/// Build a shard view of `server` honouring the ambient placement (see
/// the module doc): all-local in-process lanes by default, mixed
/// local/remote lanes against standing shard workers under the CI
/// `remote-shards` entry.
fn shard_view(server: &MaskServer, d: usize, shards: usize) -> ShardedAggregator<MaskServer> {
    match placed_spec(d, shards) {
        None => server.shard_view(shards),
        Some(spec) => {
            let placement = ShardPlacement::parse(&spec).expect("DELTAMASK_SHARD_PLACE");
            server
                .shard_view_placed(shards, &placement, ci_fingerprint(), SocketConfig::from_env())
                .expect("remote shard view")
        }
    }
}

fn logit(p: f32) -> f32 {
    let p = p.clamp(1e-6, 1.0 - 1e-6);
    (p / (1.0 - p)).ln()
}

/// A plausible round for `codec` against an arbitrary global state:
/// drifted posteriors, shared-seed masks, score mirrors — the same
/// recipe as `decode_workers.rs` / the fl_integration property tests.
fn encode_round(
    name: &str,
    plan: &RoundPlan,
    rng: &mut Xoshiro256pp,
) -> Vec<Encoded> {
    let codec = compress::by_name(name).unwrap();
    let mut encs = Vec::new();
    for slot in 0..plan.expected() {
        let theta_k: Vec<f32> = plan
            .theta_g
            .iter()
            .map(|&p| (p + 0.3 * (rng.next_f32() - 0.5)).clamp(0.01, 0.99))
            .collect();
        let s_k: Vec<f32> = theta_k.iter().map(|&p| logit(p)).collect();
        let mut mask_k = Vec::new();
        sample_mask_seeded(&theta_k, plan.seed, &mut mask_k);
        let ectx = plan.encode_ctx(slot, &theta_k, &mask_k, &s_k);
        encs.push(codec.encode(&ectx).unwrap_or_else(|e| panic!("{name}: {e}")));
    }
    encs
}

fn round_fixture(name: &str, d: usize, k: usize, trial: u64) -> (RoundPlan, Vec<Encoded>) {
    let mut rng = Xoshiro256pp::new(0x5A4D ^ trial.wrapping_mul(0x9e37_79b9));
    let theta_g: Vec<f32> = (0..d).map(|_| 0.05 + 0.9 * rng.next_f32()).collect();
    let s_g: Vec<f32> = theta_g.iter().map(|&p| logit(p)).collect();
    let mut engine = RoundEngine::new(trial, k, 1.0, 0.8, 0.25, 3);
    let plan = engine.plan(0, &theta_g, &s_g);
    let encs = encode_round(name, &plan, &mut rng);
    (plan, encs)
}

fn send_all(plan: &RoundPlan, encs: &[Encoded], order: &[usize]) -> ChannelTransport {
    let (channel, sender) = ChannelTransport::new();
    for &slot in order {
        sender
            .send(WireMessage {
                round: plan.round,
                client_id: plan.participants[slot],
                slot,
                payload: Payload::Update(encs[slot].clone()),
                enc_secs: 0.125 * (slot as f64 + 1.0),
                loss: 0.5 + slot as f32,
            })
            .unwrap();
    }
    drop(sender);
    channel
}

/// Drain one round into a fresh server. `shards == 1` is the retained
/// single-lane reference; `shards > 1` drains through a sharded view
/// stitched back with `adopt_shards`. Returns the server plus the
/// per-shard absorb timings (empty for the reference path).
fn drain_with(
    name: &str,
    plan: &RoundPlan,
    encs: &[Encoded],
    order: &[usize],
    mode: PipelineMode,
    workers: usize,
    shards: usize,
) -> (MaskServer, Vec<f64>) {
    let codec = compress::by_name(name).unwrap();
    let mut channel = send_all(plan, encs, order);
    let mut server = MaskServer::with_theta0(plan.d(), 1.0, 0.85);
    let pool = ScratchPool::new();
    let tag = || format!("{name} {mode:?} workers={workers} shards={shards}");
    if shards <= 1 {
        drain_round(
            &mut channel,
            plan,
            codec.as_ref(),
            &mut server,
            DrainConfig::new(mode, workers),
            &pool,
        )
        .unwrap_or_else(|e| panic!("{}: {e}", tag()));
        (server, Vec::new())
    } else {
        let mut view = shard_view(&server, plan.d(), shards);
        drain_round(
            &mut channel,
            plan,
            codec.as_ref(),
            &mut view,
            DrainConfig::sharded(mode, workers, shards),
            &pool,
        )
        .unwrap_or_else(|e| panic!("{}: {e}", tag()));
        let timings = view.absorb_secs_by_shard();
        server.adopt_shards(view);
        (server, timings)
    }
}

/// The tentpole property: sharded drain (any shard count, either decode
/// shape) ≡ the single-lane serial drain, bitwise, across all 11 codecs ×
/// both pipeline modes × shard counts {1, 2, 3, 8}, with varying client
/// counts and adversarial arrival orders.
#[test]
fn sharded_aggregation_is_bitwise_identical_to_single_lane_for_all_codecs() {
    let d = 2048;
    for (trial, name) in compress::all_names().iter().enumerate() {
        let k = 2 + (trial % 5); // client counts 2..=6 across the roster
        let (plan, encs) = round_fixture(name, d, k, trial as u64 + 1);
        // Adversarial arrival order: reversed with a mid-list swap.
        let mut order: Vec<usize> = (0..plan.expected()).rev().collect();
        if order.len() > 2 {
            let mid = order.len() / 2;
            order.swap(0, mid);
        }
        for mode in [PipelineMode::Batch, PipelineMode::Streaming] {
            let (reference, _) = drain_with(name, &plan, &encs, &order, mode, 1, 1);
            for shards in [1usize, 2, 3, 8] {
                // workers=1 exercises the inline decode→route path,
                // workers=3 the worker-routed path.
                for workers in [1usize, 3] {
                    let (sharded, timings) =
                        drain_with(name, &plan, &encs, &order, mode, workers, shards);
                    let tag = format!("{name} {mode:?} workers={workers} shards={shards}");
                    assert_eq!(
                        reference.theta_g, sharded.theta_g,
                        "{tag}: theta_g diverged"
                    );
                    assert_eq!(reference.s_g, sharded.s_g, "{tag}: s_g diverged");
                    assert_eq!(reference.round, sharded.round, "{tag}");
                    if shards > 1 {
                        assert_eq!(timings.len(), shard_bounds(d, shards).len(), "{tag}");
                    }
                }
            }
        }
    }
}

/// Sharding stays exact when `d` does not divide evenly (prime `d`) and
/// when the shard count resolves from 0 (= cores) or exceeds `d`.
#[test]
fn uneven_auto_and_oversized_shard_counts_match_single_lane() {
    let d = 1031; // prime: every shard boundary lands unevenly
    let (plan, encs) = round_fixture("deltamask", d, 3, 77);
    let order: Vec<usize> = (0..plan.expected()).collect();
    let (reference, _) =
        drain_with("deltamask", &plan, &encs, &order, PipelineMode::Streaming, 1, 1);
    for shards in [2usize, 7, 8] {
        let (sharded, _) = drain_with(
            "deltamask",
            &plan,
            &encs,
            &order,
            PipelineMode::Streaming,
            2,
            shards,
        );
        assert_eq!(reference.theta_g, sharded.theta_g, "shards={shards}");
    }
    // shards = 0 resolves to the core count inside drain_round; the view
    // must be built with the same resolution the drain will use.
    let resolved = DrainConfig::sharded(PipelineMode::Streaming, 1, 0).resolved_shards();
    let (sharded, _) = drain_with(
        "deltamask",
        &plan,
        &encs,
        &order,
        PipelineMode::Streaming,
        1,
        resolved,
    );
    assert_eq!(reference.theta_g, sharded.theta_g, "shards=0 (cores)");
    // Far more shards than dimensions: clamped to d, still exact.
    let (tiny_plan, tiny_encs) = round_fixture("fedpm", 5, 2, 78);
    let tiny_order = vec![1usize, 0];
    let (tiny_ref, _) = drain_with(
        "fedpm",
        &tiny_plan,
        &tiny_encs,
        &tiny_order,
        PipelineMode::Streaming,
        1,
        1,
    );
    let (tiny_sharded, timings) = drain_with(
        "fedpm",
        &tiny_plan,
        &tiny_encs,
        &tiny_order,
        PipelineMode::Streaming,
        1,
        16,
    );
    assert_eq!(tiny_ref.theta_g, tiny_sharded.theta_g);
    assert_eq!(timings.len(), 5, "16 shards over d=5 clamp to 5 lanes");
}

/// Multi-round trajectories: re-viewing and re-stitching the server every
/// round (exactly what the Runner does) stays bitwise-identical to the
/// monolithic server across rounds — including across the ⌈1/ρ⌉ prior
/// reset, which each shard must apply on the same schedule.
#[test]
fn multi_round_sharded_trajectory_matches_monolithic() {
    let d = 523;
    for name in ["deltamask", "eden"] {
        // ρ=0.5 ⇒ the Alg. 2 prior reset fires on rounds 0 and 2.
        let mut mono = MaskServer::with_theta0(d, 0.5, 0.85);
        let mut split = mono.clone();
        let mut engine_m = RoundEngine::new(11, 4, 1.0, 0.8, 0.25, 4);
        let mut engine_s = RoundEngine::new(11, 4, 1.0, 0.8, 0.25, 4);
        for round in 0..4 {
            let plan_m = engine_m.plan(round, &mono.theta_g, &mono.s_g);
            let plan_s = engine_s.plan(round, &split.theta_g, &split.s_g);
            assert_eq!(plan_m.seed, plan_s.seed, "{name} round {round}");
            let mut rng = Xoshiro256pp::new(0xF0 ^ round as u64);
            let encs = encode_round(name, &plan_m, &mut rng);
            let order: Vec<usize> = (0..plan_m.expected()).rev().collect();

            let codec = compress::by_name(name).unwrap();
            let pool = ScratchPool::new();
            let mut channel = send_all(&plan_m, &encs, &order);
            drain_round(
                &mut channel,
                &plan_m,
                codec.as_ref(),
                &mut mono,
                DrainConfig::serial(PipelineMode::Streaming),
                &pool,
            )
            .unwrap();

            let mut channel = send_all(&plan_s, &encs, &order);
            let mut view = shard_view(&split, d, 3);
            drain_round(
                &mut channel,
                &plan_s,
                codec.as_ref(),
                &mut view,
                DrainConfig::sharded(PipelineMode::Streaming, 2, 3),
                &pool,
            )
            .unwrap();
            split.adopt_shards(view);

            assert_eq!(mono.theta_g, split.theta_g, "{name} round {round}");
            assert_eq!(mono.s_g, split.s_g, "{name} round {round}");
            assert_eq!(mono.round, split.round, "{name} round {round}");
        }
    }
}

/// Error path: a malformed record under sharded absorb must abort the
/// round with a clean error — decode workers joined, every shard lane
/// joined (the drain calls `abort_round` on the view), and the view still
/// decomposable afterwards. A fresh view then drains the corrected round
/// bitwise-identically to the reference, proving nothing was poisoned.
#[test]
fn malformed_record_under_sharded_absorb_aborts_cleanly() {
    let (plan, mut encs) = round_fixture("deltamask", 512, 4, 9);
    let good = encs[2].clone();
    encs[2] = Encoded {
        bytes: vec![0u8; 8], // fails DeltaMask's record-length validation
    };
    let order: Vec<usize> = (0..plan.expected()).collect();
    let codec = compress::by_name("deltamask").unwrap();
    for mode in [PipelineMode::Batch, PipelineMode::Streaming] {
        for workers in [1usize, 3] {
            let mut channel = send_all(&plan, &encs, &order);
            let server = MaskServer::with_theta0(plan.d(), 1.0, 0.85);
            let mut view = shard_view(&server, plan.d(), 4);
            let err = drain_round(
                &mut channel,
                &plan,
                codec.as_ref(),
                &mut view,
                DrainConfig::sharded(mode, workers, 4),
                &ScratchPool::new(),
            )
            .unwrap_err();
            let msg = err.to_string();
            assert!(
                msg.contains("decode failed for slot 2"),
                "{mode:?} workers={workers}: unexpected error: {msg}"
            );
            // All four lanes joined and handed their slices back.
            assert_eq!(view.shard_count(), 4);
            assert_eq!(view.into_shards().len(), 4);
        }
    }
    // Corrected round through a fresh view: bitwise-identical recovery.
    encs[2] = good;
    let (reference, _) =
        drain_with("deltamask", &plan, &encs, &order, PipelineMode::Streaming, 1, 1);
    let (recovered, _) = drain_with(
        "deltamask",
        &plan,
        &encs,
        &order,
        PipelineMode::Streaming,
        3,
        4,
    );
    assert_eq!(reference.theta_g, recovered.theta_g);
    assert_eq!(reference.s_g, recovered.s_g);
}

/// Mixed local/remote placement through the REAL drain paths: an
/// in-thread `serve_shard_worker::<MaskServer>` owns shard 1's slice
/// while shard 0 stays in-process, and the drained round must be bitwise
/// identical to the all-local sharded drain for both pipeline modes and
/// both decode-stage shapes — the [`ShardLane`] trait boundary is
/// invisible to the router, the drains and the stitch, even across an
/// uneven (prime-`d`) shard boundary.
#[test]
fn mixed_placement_drain_is_bitwise_identical_to_all_local() {
    let fp = ci_fingerprint();
    let scfg = SocketConfig::default();
    let path = std::env::temp_dir().join(format!("dm-agg-mixed-{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let spec = SocketAddrSpec::Uds(path.clone());
    let listener = Listener::bind(&spec).unwrap();
    // A lingering worker serves one session per drained view below (each
    // `adopt_shards` retires its view, which sends a shutdown the linger
    // mode ignores). The thread parks in `accept` forever; it is detached
    // on purpose, exactly like the CI standing workers it mirrors.
    std::thread::spawn(move || serve_shard_worker::<MaskServer>(&listener, scfg, fp, true));

    let d = 1031; // prime: the two-shard boundary lands unevenly
    let (plan, encs) = round_fixture("deltamask", d, 4, 33);
    let order: Vec<usize> = (0..plan.expected()).rev().collect();
    let codec = compress::by_name("deltamask").unwrap();
    let placement = ShardPlacement::parse(&format!("local,uds:{}", path.display())).unwrap();
    for mode in [PipelineMode::Batch, PipelineMode::Streaming] {
        for workers in [1usize, 3] {
            let tag = format!("{mode:?} workers={workers}");
            let mut channel = send_all(&plan, &encs, &order);
            let mut reference = MaskServer::with_theta0(d, 1.0, 0.85);
            let mut view = reference.shard_view(2);
            drain_round(
                &mut channel,
                &plan,
                codec.as_ref(),
                &mut view,
                DrainConfig::sharded(mode, workers, 2),
                &ScratchPool::new(),
            )
            .unwrap_or_else(|e| panic!("{tag} (local): {e}"));
            reference.adopt_shards(view);

            let mut channel = send_all(&plan, &encs, &order);
            let mut placed = MaskServer::with_theta0(d, 1.0, 0.85);
            let mut view = placed
                .shard_view_placed(2, &placement, fp, scfg)
                .unwrap_or_else(|e| panic!("{tag}: shard worker unreachable: {e}"));
            drain_round(
                &mut channel,
                &plan,
                codec.as_ref(),
                &mut view,
                DrainConfig::sharded(mode, workers, 2),
                &ScratchPool::new(),
            )
            .unwrap_or_else(|e| panic!("{tag} (placed): {e}"));
            assert!(view.lane_fault().is_none(), "{tag}: unexpected lane fault");
            placed.adopt_shards(view);

            assert_eq!(reference.theta_g, placed.theta_g, "{tag}: theta_g diverged");
            assert_eq!(reference.s_g, placed.s_g, "{tag}: s_g diverged");
            assert_eq!(reference.round, placed.round, "{tag}: round counter");
        }
    }
    let _ = std::fs::remove_file(&path);
}

// ---------------------------------------------------------------------
// Round-resident pipeline (persistent workers / lanes / pools)
// ---------------------------------------------------------------------

/// Drive `rounds` rounds through ONE [`DrainPipeline`] (resident decode
/// workers + pool) and — when `shards > 1` — ONE resident shard view
/// (resident lanes + lane pools + pseudo-count slices), syncing θ_g/s_g
/// back each round and stitching fully at the end. Returns the final
/// server plus the total pool misses (pipeline pool + lane pools).
fn drain_trajectory_resident(
    name: &str,
    d: usize,
    rounds: usize,
    mode: PipelineMode,
    workers: usize,
    shards: usize,
) -> (MaskServer, u64) {
    let codec: Arc<dyn UpdateCodec> = Arc::from(compress::by_name(name).unwrap());
    let pipeline = DrainPipeline::new(DrainConfig::sharded(mode, workers, shards));
    let mut server = MaskServer::with_theta0(d, 0.5, 0.85); // ρ=0.5 ⇒ prior reset rounds 0, 2
    let mut view: Option<ShardedAggregator<MaskServer>> =
        (shards > 1).then(|| shard_view(&server, d, shards));
    let mut engine = RoundEngine::new(11, 4, 1.0, 0.8, 0.25, rounds);
    for round in 0..rounds {
        let plan = Arc::new(engine.plan(round, &server.theta_g, &server.s_g));
        let mut rng = Xoshiro256pp::new(0xAB ^ round as u64);
        let encs = encode_round(name, &plan, &mut rng);
        let order: Vec<usize> = (0..plan.expected()).rev().collect();
        let mut channel = send_all(&plan, &encs, &order);
        let tag = || format!("{name} {mode:?} workers={workers} shards={shards} round={round}");
        match view.as_mut() {
            Some(view) => {
                pipeline
                    .drain_round(&mut channel, &plan, &codec, view)
                    .unwrap_or_else(|e| panic!("{}: {e}", tag()));
                server.sync_from_shards(view);
            }
            None => {
                pipeline
                    .drain_round(&mut channel, &plan, &codec, &mut server)
                    .unwrap_or_else(|e| panic!("{}: {e}", tag()));
            }
        }
    }
    let lane_misses = view.as_ref().map_or(0, |v| v.lane_pool_stats().misses);
    if let Some(view) = view {
        server.adopt_shards(view);
    }
    (server, pipeline.pool().stats().misses + lane_misses)
}

/// The per-round-spawn oracle for the same trajectory: serial
/// `drain_round` with identical engine/encode seeds.
fn drain_trajectory_serial(name: &str, d: usize, rounds: usize, mode: PipelineMode) -> MaskServer {
    let codec = compress::by_name(name).unwrap();
    let mut server = MaskServer::with_theta0(d, 0.5, 0.85);
    let mut engine = RoundEngine::new(11, 4, 1.0, 0.8, 0.25, rounds);
    let pool = ScratchPool::new();
    for round in 0..rounds {
        let plan = engine.plan(round, &server.theta_g, &server.s_g);
        let mut rng = Xoshiro256pp::new(0xAB ^ round as u64);
        let encs = encode_round(name, &plan, &mut rng);
        let order: Vec<usize> = (0..plan.expected()).rev().collect();
        let mut channel = send_all(&plan, &encs, &order);
        drain_round(
            &mut channel,
            &plan,
            codec.as_ref(),
            &mut server,
            DrainConfig::serial(mode),
            &pool,
        )
        .unwrap_or_else(|e| panic!("{name} serial round {round}: {e}"));
    }
    server
}

/// The round-resident tentpole property: a multi-round trajectory through
/// persistent workers/lanes/pools — across the ⌈1/ρ⌉ prior reset — is
/// bitwise identical to the per-round-spawn serial path, for all 11 codecs
/// × both pipeline modes × worker/shard combinations (resident decode
/// crew only, resident lanes only, both).
#[test]
fn persistent_pipeline_matches_per_round_spawn_for_all_codecs() {
    let d = 512;
    let rounds = 3;
    for name in compress::all_names() {
        for mode in [PipelineMode::Batch, PipelineMode::Streaming] {
            let oracle = drain_trajectory_serial(name, d, rounds, mode);
            for (workers, shards) in [(3usize, 1usize), (1, 3), (3, 3)] {
                let (resident, _) =
                    drain_trajectory_resident(name, d, rounds, mode, workers, shards);
                let tag = format!("{name} {mode:?} workers={workers} shards={shards}");
                assert_eq!(oracle.theta_g, resident.theta_g, "{tag}: theta_g diverged");
                assert_eq!(oracle.s_g, resident.s_g, "{tag}: s_g diverged");
                assert_eq!(oracle.round, resident.round, "{tag}: round counter");
            }
        }
    }
}

/// A malformed record mid-trajectory aborts that round cleanly and leaves
/// the SAME resident pipeline + view reusable: the following good rounds
/// drain through the same parked workers/lanes, and the final state is
/// bitwise identical to a serial replay of the good rounds only.
#[test]
fn persistent_pipeline_survives_malformed_round_and_stays_reusable() {
    let d = 512;
    let name = "deltamask";
    let codec: Arc<dyn UpdateCodec> = Arc::from(compress::by_name(name).unwrap());
    for mode in [PipelineMode::Batch, PipelineMode::Streaming] {
        let pipeline = DrainPipeline::new(DrainConfig::sharded(mode, 3, 4));
        let mut server = MaskServer::with_theta0(d, 1.0, 0.85);
        let mut view = shard_view(&server, d, 4);
        let mut oracle = MaskServer::with_theta0(d, 1.0, 0.85);
        let oracle_pool = ScratchPool::new();
        let serial_codec = compress::by_name(name).unwrap();
        let mut engine = RoundEngine::new(17, 4, 1.0, 0.8, 0.25, 3);
        let mut engine_o = RoundEngine::new(17, 4, 1.0, 0.8, 0.25, 3);
        for round in 0..3 {
            let plan = Arc::new(engine.plan(round, &server.theta_g, &server.s_g));
            let plan_o = engine_o.plan(round, &oracle.theta_g, &oracle.s_g);
            let mut rng = Xoshiro256pp::new(0xCC ^ round as u64);
            let mut encs = encode_round(name, &plan, &mut rng);
            let order: Vec<usize> = (0..plan.expected()).collect();
            if round == 1 {
                // Corrupt one record: this round must abort...
                encs[2] = Encoded { bytes: vec![0; 8] };
                let mut channel = send_all(&plan, &encs, &order);
                let err = pipeline
                    .drain_round(&mut channel, &plan, &codec, &mut view)
                    .unwrap_err();
                assert!(
                    err.to_string().contains("decode failed for slot 2"),
                    "{mode:?}: {err}"
                );
                // ...and the oracle skips it entirely (its engine still
                // consumed the round's sampling draw above).
                continue;
            }
            let mut channel = send_all(&plan, &encs, &order);
            pipeline
                .drain_round(&mut channel, &plan, &codec, &mut view)
                .unwrap_or_else(|e| panic!("{mode:?} round {round}: {e}"));
            server.sync_from_shards(&view);

            let mut channel = send_all(&plan_o, &encs, &order);
            drain_round(
                &mut channel,
                &plan_o,
                serial_codec.as_ref(),
                &mut oracle,
                DrainConfig::serial(mode),
                &oracle_pool,
            )
            .unwrap_or_else(|e| panic!("{mode:?} oracle round {round}: {e}"));
            assert_eq!(server.theta_g, oracle.theta_g, "{mode:?} round {round}");
            assert_eq!(server.s_g, oracle.s_g, "{mode:?} round {round}");
        }
        server.adopt_shards(view);
        assert_eq!(server.theta_g, oracle.theta_g, "{mode:?} after stitch");
    }
}

/// The zero-alloc claim, observable: with one record per round the pool
/// concurrency is deterministic, so under the resident pipeline + view
/// the miss counters must freeze after the warm-up round — steady-state
/// rounds (round ≥ 2, per the per-round-spawn comparison baseline)
/// allocate **zero** new decode buffers.
#[test]
fn resident_steady_state_rounds_allocate_zero_decode_buffers() {
    let d = 512;
    let rounds = 5;
    for (name, workers, shards) in [
        ("deltamask", 3usize, 2usize), // range-decoded straight into lane pools
        ("fedpm", 3, 2),               // full decode (unpooled codec), split via lane pools
        ("deltamask", 3, 1),           // resident decode crew + pipeline pool only
    ] {
        let codec: Arc<dyn UpdateCodec> = Arc::from(compress::by_name(name).unwrap());
        let pipeline =
            DrainPipeline::new(DrainConfig::sharded(PipelineMode::Streaming, workers, shards));
        let mut server = MaskServer::with_theta0(d, 1.0, 0.85);
        let mut view: Option<ShardedAggregator<MaskServer>> =
            (shards > 1).then(|| shard_view(&server, d, shards));
        let mut engine = RoundEngine::new(5, 1, 1.0, 0.8, 0.25, rounds);
        let mut misses_after: Vec<u64> = Vec::new();
        for round in 0..rounds {
            let plan = Arc::new(engine.plan(round, &server.theta_g, &server.s_g));
            let mut rng = Xoshiro256pp::new(0x2A ^ round as u64);
            let encs = encode_round(name, &plan, &mut rng);
            let mut channel = send_all(&plan, &encs, &[0]);
            match view.as_mut() {
                Some(view) => {
                    pipeline
                        .drain_round(&mut channel, &plan, &codec, view)
                        .unwrap_or_else(|e| panic!("{name} round {round}: {e}"));
                    server.sync_from_shards(view);
                }
                None => {
                    pipeline
                        .drain_round(&mut channel, &plan, &codec, &mut server)
                        .unwrap_or_else(|e| panic!("{name} round {round}: {e}"));
                }
            }
            let lane = view.as_ref().map_or(0, |v| v.lane_pool_stats().misses);
            misses_after.push(pipeline.pool().stats().misses + lane);
        }
        assert!(misses_after[0] > 0, "{name}: warm-up must allocate something");
        for r in 2..rounds {
            assert_eq!(
                misses_after[r], misses_after[1],
                "{name} workers={workers} shards={shards}: steady-state round {r} \
                 allocated new decode buffers ({misses_after:?})"
            );
        }
    }
}

/// With realistic concurrency (k records racing through W workers into S
/// lanes) the exact warm-up size is scheduling-dependent, but the resident
/// pools' total misses stay **hard-bounded by the in-flight caps**,
/// independent of how many rounds run — whereas per-round-spawn lane pools
/// re-allocate every round. (Bound: W full buffers in flight on the
/// pipeline pool; per lane, 4 queued [the lane queue cap] + W being built
/// + 1 being absorbed sub-buffers.)
#[test]
fn resident_pool_misses_are_bounded_across_rounds() {
    let d = 768;
    let rounds = 6;
    let (workers, shards) = (3usize, 2usize);
    let (_, misses) =
        drain_trajectory_resident("fedpm", d, rounds, PipelineMode::Streaming, workers, shards);
    let bound = (workers + shards * (4 + workers + 1)) as u64;
    assert!(
        misses <= bound,
        "resident pools must not re-warm per round: {misses} misses > bound {bound}"
    );
}

/// `DrainConfig::shards > 1` against a plain (single-lane) aggregator is
/// a coordinator misconfiguration: the drain must reject it with a clear
/// error instead of silently falling back.
#[test]
fn sharded_drain_requires_a_sharded_aggregator() {
    let (plan, encs) = round_fixture("fedpm", 256, 2, 21);
    let order: Vec<usize> = (0..plan.expected()).collect();
    let codec = compress::by_name("fedpm").unwrap();
    let mut channel = send_all(&plan, &encs, &order);
    let mut server = MaskServer::with_theta0(plan.d(), 1.0, 0.85);
    let err = drain_round(
        &mut channel,
        &plan,
        codec.as_ref(),
        &mut server,
        DrainConfig::sharded(PipelineMode::Streaming, 1, 4),
        &ScratchPool::new(),
    )
    .unwrap_err();
    assert!(
        err.to_string().contains("dimension-sharded aggregator"),
        "{err}"
    );
}
