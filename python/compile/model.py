"""Layer-2: the masked foundation-model compute graphs, in JAX.

The simulated FM (DESIGN.md §2 substitution table) is a frozen feature
extractor followed by ``L`` maskable residual dense blocks — the stand-in
for "the last five transformer blocks" the paper masks (§4) — plus a linear
classifier head:

    h₀ = x (frozen-backbone features)
    hᵢ = hᵢ₋₁ + relu((mᵢ ⊙ Wᵢ) hᵢ₋₁)       i = 1..L   (Pallas kernels)
    logits = W_head h_L + b_head

Four graphs are AOT-lowered per (F, C) combo and executed from rust:

* ``train_step`` — one stochastic-mask Adam step on the scores ``s``
  (lr=0.1, paper App. C.1) with the straight-through estimator through the
  Bernoulli sample ``m = 1[u < σ(s)]``. The uniforms ``u`` are an *input*
  so the rust coordinator owns all randomness (shared-seed determinism,
  §3.2).
* ``eval_step``  — logits for an explicit binary/soft mask.
* ``lp_step``    — linear probing: Adam on the head only, mask ≡ 1
  (the paper's §3.3 single-round head initialization).
* ``ft_step``    — the fine-tuning baseline: Adam on blocks + head.

Python runs only at build time; ``aot.py`` lowers these to HLO text.
"""

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels.masked_linear import masked_linear

# Paper App. C.1: Adam with lr 0.1 on mask scores.
MASK_LR = 0.1
# Head / weight training rates for the LP and FT graphs.
LP_LR = 0.01
FT_LR = 3e-3
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


@dataclass(frozen=True)
class ModelConfig:
    """Static shape configuration for one lowered artifact family."""

    name: str  # architecture simulation name, e.g. "vitb32"
    F: int  # block width (frozen feature dim)
    C: int  # number of classes
    B: int = 64  # batch size (paper App. C.1)
    L: int = 5  # maskable blocks (paper §4: "last five blocks")

    @property
    def d(self) -> int:
        """Mask dimensionality — the paper's d."""
        return self.L * self.F * self.F


def adam_update(p, g, mt, vt, t, lr):
    """One Adam step; ``t`` is the 1-based step count (f32 scalar)."""
    mt = ADAM_B1 * mt + (1.0 - ADAM_B1) * g
    vt = ADAM_B2 * vt + (1.0 - ADAM_B2) * g * g
    mhat = mt / (1.0 - ADAM_B1**t)
    vhat = vt / (1.0 - ADAM_B2**t)
    p = p - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return p, mt, vt


def make_forward(cfg: ModelConfig, trainable_weights: bool = False):
    """fwd(x, w_blocks, masks, head_w, head_b) -> logits, scanning the L
    masked blocks (scan keeps the lowered HLO compact).

    ``trainable_weights=False`` (default) routes through the L1 Pallas
    ``masked_linear`` whose custom VJP freezes the weights (zero cotangent)
    — the DeltaMask/FedPM regime. ``trainable_weights=True`` uses the plain
    jnp expression so weight gradients flow — only the conventional
    fine-tuning baseline (``ft_step``) needs this, since by definition it
    *is* weight training.
    """

    def block(h, w, m):
        if trainable_weights:
            return h + jax.nn.relu(h @ (w * m).T)
        return h + jax.nn.relu(masked_linear(h, w, m))

    def forward(x, w_blocks, masks, head_w, head_b):
        def body(h, wm):
            w, m = wm
            return block(h, w, m), None

        h, _ = jax.lax.scan(body, x, (w_blocks, masks))
        return h @ head_w.T + head_b

    return forward


def cross_entropy(logits, y_onehot):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


def make_train_step(cfg: ModelConfig):
    """Stochastic mask training (Alg. 1, ClientUpdate inner loop body)."""
    forward = make_forward(cfg)

    def train_step(s, mt, vt, t, w_blocks, head_w, head_b, x, y_onehot, u):
        def loss_fn(s):
            theta = jax.nn.sigmoid(s)
            hard = (u < theta).astype(jnp.float32)
            # Straight-through: forward uses the Bernoulli sample, backward
            # flows through theta as if m were theta (∂m/∂θ ≈ 1).
            m = theta + jax.lax.stop_gradient(hard - theta)
            masks = m.reshape(cfg.L, cfg.F, cfg.F)
            logits = forward(x, w_blocks, masks, head_w, head_b)
            return cross_entropy(logits, y_onehot)

        loss, g = jax.value_and_grad(loss_fn)(s)
        s, mt, vt = adam_update(s, g, mt, vt, t, MASK_LR)
        return s, mt, vt, loss

    return train_step


def make_eval_step(cfg: ModelConfig):
    """Logits under an explicit mask (server-side evaluation; also used by
    every masking baseline)."""
    forward = make_forward(cfg)

    def eval_step(mask, w_blocks, head_w, head_b, x):
        masks = mask.reshape(cfg.L, cfg.F, cfg.F)
        return forward(x, w_blocks, masks, head_w, head_b)

    return eval_step


def make_lp_step(cfg: ModelConfig):
    """Linear probing: one Adam step on (head_w, head_b), backbone frozen
    with mask ≡ 1 (§3.3 weight-initialization round)."""
    forward = make_forward(cfg)

    def lp_step(head_w, head_b, m_hw, v_hw, m_hb, v_hb, t, w_blocks, x, y_onehot):
        ones = jnp.ones((cfg.L, cfg.F, cfg.F), jnp.float32)

        def loss_fn(hw, hb):
            logits = forward(x, w_blocks, ones, hw, hb)
            return cross_entropy(logits, y_onehot)

        loss, (g_hw, g_hb) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
            head_w, head_b
        )
        head_w, m_hw, v_hw = adam_update(head_w, g_hw, m_hw, v_hw, t, LP_LR)
        head_b, m_hb, v_hb = adam_update(head_b, g_hb, m_hb, v_hb, t, LP_LR)
        return head_w, head_b, m_hw, v_hw, m_hb, v_hb, loss

    return lp_step


def make_ft_step(cfg: ModelConfig):
    """Fine-tuning baseline: Adam on the maskable blocks + head (the paper
    fine-tunes exactly "the layers modified in DeltaMask", App. C.2)."""
    forward = make_forward(cfg, trainable_weights=True)

    def ft_step(
        w_blocks, head_w, head_b,
        m_wb, v_wb, m_hw, v_hw, m_hb, v_hb,
        t, x, y_onehot,
    ):
        ones = jnp.ones((cfg.L, cfg.F, cfg.F), jnp.float32)

        def loss_fn(wb, hw, hb):
            logits = forward(x, wb, ones, hw, hb)
            return cross_entropy(logits, y_onehot)

        loss, (g_wb, g_hw, g_hb) = jax.value_and_grad(loss_fn, argnums=(0, 1, 2))(
            w_blocks, head_w, head_b
        )
        w_blocks, m_wb, v_wb = adam_update(w_blocks, g_wb, m_wb, v_wb, t, FT_LR)
        head_w, m_hw, v_hw = adam_update(head_w, g_hw, m_hw, v_hw, t, FT_LR)
        head_b, m_hb, v_hb = adam_update(head_b, g_hb, m_hb, v_hb, t, FT_LR)
        return w_blocks, head_w, head_b, m_wb, v_wb, m_hw, v_hw, m_hb, v_hb, loss

    return ft_step


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def graph_specs(cfg: ModelConfig):
    """Input specs for every lowered graph — the contract the rust runtime
    reads back from ``manifest.json``. Names match the function params."""
    d, L, F, C, B = cfg.d, cfg.L, cfg.F, cfg.C, cfg.B
    return {
        "train": {
            "fn": make_train_step(cfg),
            "inputs": [
                ("s", (d,)), ("mt", (d,)), ("vt", (d,)), ("t", ()),
                ("w_blocks", (L, F, F)), ("head_w", (C, F)), ("head_b", (C,)),
                ("x", (B, F)), ("y_onehot", (B, C)), ("u", (d,)),
            ],
            "outputs": [("s", (d,)), ("mt", (d,)), ("vt", (d,)), ("loss", ())],
        },
        "eval": {
            "fn": make_eval_step(cfg),
            "inputs": [
                ("mask", (d,)), ("w_blocks", (L, F, F)),
                ("head_w", (C, F)), ("head_b", (C,)), ("x", (B, F)),
            ],
            "outputs": [("logits", (B, C))],
        },
        "lp": {
            "fn": make_lp_step(cfg),
            "inputs": [
                ("head_w", (C, F)), ("head_b", (C,)),
                ("m_hw", (C, F)), ("v_hw", (C, F)),
                ("m_hb", (C,)), ("v_hb", (C,)), ("t", ()),
                ("w_blocks", (L, F, F)), ("x", (B, F)), ("y_onehot", (B, C)),
            ],
            "outputs": [
                ("head_w", (C, F)), ("head_b", (C,)),
                ("m_hw", (C, F)), ("v_hw", (C, F)),
                ("m_hb", (C,)), ("v_hb", (C,)), ("loss", ()),
            ],
        },
        "ft": {
            "fn": make_ft_step(cfg),
            "inputs": [
                ("w_blocks", (L, F, F)), ("head_w", (C, F)), ("head_b", (C,)),
                ("m_wb", (L, F, F)), ("v_wb", (L, F, F)),
                ("m_hw", (C, F)), ("v_hw", (C, F)),
                ("m_hb", (C,)), ("v_hb", (C,)), ("t", ()),
                ("x", (B, F)), ("y_onehot", (B, C)),
            ],
            "outputs": [
                ("w_blocks", (L, F, F)), ("head_w", (C, F)), ("head_b", (C,)),
                ("m_wb", (L, F, F)), ("v_wb", (L, F, F)),
                ("m_hw", (C, F)), ("v_hw", (C, F)),
                ("m_hb", (C,)), ("v_hb", (C,)), ("loss", ()),
            ],
        },
    }
