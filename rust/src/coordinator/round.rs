//! Round planning: participant sampling, the κ schedule, per-round seeds
//! and the shared-seed global mask — everything a round broadcasts, frozen
//! into an immutable [`RoundPlan`] snapshot.

use crate::compress::{DecodeCtx, EncodeCtx};
use crate::model::{kappa_schedule, sample_mask_seeded};
use crate::util::rng::Xoshiro256pp;

/// Immutable broadcast state for one federated round.
///
/// Every decode context borrows from the plan, not from the live server:
/// streaming aggregation mutates `MaskServer::{alpha,beta,s_g}` while later
/// updates are still in flight, so decoders must see the round-start
/// snapshot (θ^{g,t-1}, s^{g,t-1}, m^{g,t-1}) the clients encoded against.
#[derive(Clone, Debug)]
pub struct RoundPlan {
    pub round: usize,
    /// Public per-round seed: derives m^{g,t-1} on every party (§3.2) and,
    /// xor-ed with the client id, each client's codec seed.
    pub seed: u64,
    /// Top-κ fraction from the cosine schedule.
    pub kappa: f64,
    /// Sampled client ids, in slot order (slot i ↔ participants[i]).
    pub participants: Vec<usize>,
    /// Shared-seed global binary mask m^{g,t-1}.
    pub mask_g: Vec<f32>,
    /// Broadcast global probabilities θ^{g,t-1}.
    pub theta_g: Vec<f32>,
    /// Broadcast score mirror s^{g,t-1} (delta-family reference point).
    pub s_g: Vec<f32>,
}

impl RoundPlan {
    /// Mask dimensionality.
    pub fn d(&self) -> usize {
        self.theta_g.len()
    }

    /// Number of updates the server expects this round.
    pub fn expected(&self) -> usize {
        self.participants.len()
    }

    /// Deterministic codec seed for the client in `slot` — known to both
    /// parties without transmission.
    pub fn client_seed(&self, slot: usize) -> u64 {
        self.seed ^ self.participants[slot] as u64
    }

    /// Server-side decode context for `slot`, borrowing the round snapshot.
    pub fn decode_ctx(&self, slot: usize) -> DecodeCtx<'_> {
        DecodeCtx {
            d: self.d(),
            mask_g: &self.mask_g,
            s_g: &self.s_g,
            seed: self.client_seed(slot),
        }
    }

    /// Client-side encode context for `slot`, combining the broadcast
    /// snapshot with the client's freshly-trained local state.
    pub fn encode_ctx<'a>(
        &'a self,
        slot: usize,
        theta_k: &'a [f32],
        mask_k: &'a [f32],
        s_k: &'a [f32],
    ) -> EncodeCtx<'a> {
        EncodeCtx {
            d: self.d(),
            theta_k,
            theta_g: &self.theta_g,
            mask_k,
            mask_g: &self.mask_g,
            s_k,
            s_g: &self.s_g,
            kappa: self.kappa,
            seed: self.client_seed(slot),
        }
    }
}

/// Owns the cross-round scheduling state: the participant-sampling RNG and
/// the experiment geometry (N, ρ, κ schedule, horizon).
///
/// ```
/// use deltamask::coordinator::RoundEngine;
/// let theta = vec![0.5f32; 8];
/// let s = vec![0.0f32; 8];
/// // seed 42, 4 clients, ρ=1 (full participation), κ₀=0.8 → 0.25, 10 rounds.
/// let mut engine = RoundEngine::new(42, 4, 1.0, 0.8, 0.25, 10);
/// let plan = engine.plan(0, &theta, &s);
/// assert_eq!(plan.expected(), 4); // ρ=1 ⇒ every client participates
/// assert_eq!(plan.d(), 8);
/// // Decode contexts borrow the plan's broadcast snapshot, never live state.
/// let ctx = plan.decode_ctx(2);
/// assert_eq!(ctx.seed, plan.client_seed(2));
/// ```
#[derive(Debug)]
pub struct RoundEngine {
    n_clients: usize,
    rho: f64,
    kappa0: f64,
    kappa_floor: f64,
    total_rounds: usize,
    base_seed: u64,
    rng: Xoshiro256pp,
}

impl RoundEngine {
    pub fn new(
        base_seed: u64,
        n_clients: usize,
        rho: f64,
        kappa0: f64,
        kappa_floor: f64,
        total_rounds: usize,
    ) -> Self {
        Self {
            n_clients,
            rho,
            kappa0,
            kappa_floor,
            total_rounds,
            base_seed,
            rng: Xoshiro256pp::new(base_seed ^ 0x5e_1e_c7),
        }
    }

    /// The public per-round seed (same derivation on every party).
    pub fn round_seed(&self, round: usize) -> u64 {
        self.base_seed ^ (round as u64).wrapping_mul(0xa076_1d64_78bd_642f)
    }

    /// Sample ⌈ρ·N⌉ participants for the next round. Advances the engine
    /// RNG — call exactly once per round.
    pub fn sample_participants(&mut self) -> Vec<usize> {
        let k = ((self.rho * self.n_clients as f64).round() as usize).clamp(1, self.n_clients);
        self.rng.choose(self.n_clients, k)
    }

    /// Build the full broadcast plan for `round` from the current global
    /// state (θ_g, s_g are snapshotted into the plan).
    pub fn plan(&mut self, round: usize, theta_g: &[f32], s_g: &[f32]) -> RoundPlan {
        let seed = self.round_seed(round);
        let kappa = kappa_schedule(self.kappa0, round, self.total_rounds, self.kappa_floor);
        let mut mask_g = Vec::new();
        sample_mask_seeded(theta_g, seed, &mut mask_g);
        RoundPlan {
            round,
            seed,
            kappa,
            participants: self.sample_participants(),
            mask_g,
            theta_g: theta_g.to_vec(),
            s_g: s_g.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plans_are_deterministic_per_seed() {
        let theta = vec![0.5f32; 64];
        let s = vec![0.0f32; 64];
        let mut a = RoundEngine::new(42, 10, 0.5, 0.8, 0.25, 10);
        let mut b = RoundEngine::new(42, 10, 0.5, 0.8, 0.25, 10);
        for round in 0..4 {
            let pa = a.plan(round, &theta, &s);
            let pb = b.plan(round, &theta, &s);
            assert_eq!(pa.participants, pb.participants, "round {round}");
            assert_eq!(pa.mask_g, pb.mask_g);
            assert_eq!(pa.seed, pb.seed);
            assert_eq!(pa.expected(), 5);
        }
        let mut c = RoundEngine::new(43, 10, 0.5, 0.8, 0.25, 10);
        let pc = c.plan(0, &theta, &s);
        let pa0 = RoundEngine::new(42, 10, 0.5, 0.8, 0.25, 10).plan(0, &theta, &s);
        assert_ne!(pa0.seed, pc.seed);
    }

    #[test]
    fn participant_count_clamps() {
        let theta = vec![0.5f32; 8];
        let s = vec![0.0f32; 8];
        // ρ→0 still samples one client; ρ=1 samples all, each exactly once.
        let mut tiny = RoundEngine::new(1, 6, 1e-9, 0.8, 0.25, 3);
        assert_eq!(tiny.plan(0, &theta, &s).expected(), 1);
        let mut full = RoundEngine::new(1, 6, 1.0, 0.8, 0.25, 3);
        let mut ids = full.plan(0, &theta, &s).participants;
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn contexts_borrow_the_snapshot() {
        let theta = vec![0.25f32; 32];
        let s = vec![-1.0986f32; 32];
        let mut eng = RoundEngine::new(7, 4, 1.0, 0.8, 1.0, 2);
        let plan = eng.plan(1, &theta, &s);
        let slot = 2;
        let dctx = plan.decode_ctx(slot);
        assert_eq!(dctx.d, 32);
        assert_eq!(dctx.seed, plan.seed ^ plan.participants[slot] as u64);
        // κ floor_frac = 1.0 ⇒ constant schedule.
        assert!((plan.kappa - 0.8).abs() < 1e-12);
    }
}
