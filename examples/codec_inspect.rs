//! Codec anatomy: walk one DeltaMask update through every §3.2 stage and
//! print what each contributes — Δ size, top-κ selection, filter bits,
//! PNG packing, and server-side reconstruction fidelity.
//!
//!     cargo run --release --example codec_inspect -- [--d 327680] [--drift 0.02]

use deltamask::codec::png;
use deltamask::compress::{DecodeCtx, DeltaMaskCodec, EncodeCtx, Update, UpdateCodec};
use deltamask::filters::MembershipFilter;
use deltamask::model::sample_mask_seeded;
use deltamask::util::cli::Args;
use deltamask::util::rng::Xoshiro256pp;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let d = args.usize("d", 327_680); // ViT-B/32 sim: 5·256² mask params
    let drift = args.f64("drift", 0.02) as f32;
    let kappa = args.f64("kappa", 0.8);
    let mut rng = Xoshiro256pp::new(11);

    // Global probabilities and a client that drifted on `drift` of coords.
    let theta_g: Vec<f32> = (0..d)
        .map(|_| if rng.next_f32() < 0.5 { 0.95 } else { 0.05 })
        .collect();
    let mut theta_k = theta_g.clone();
    for t in theta_k.iter_mut() {
        if rng.next_f32() < drift {
            *t = 1.0 - *t; // confident flip — a "learned" update
        }
    }
    let round_seed = 99u64;
    let mut mask_g = Vec::new();
    sample_mask_seeded(&theta_g, round_seed, &mut mask_g);
    let mut mask_k = Vec::new();
    sample_mask_seeded(&theta_k, round_seed, &mut mask_k); // shared seed (§3.2)

    let n_delta = (0..d).filter(|&i| mask_g[i] != mask_k[i]).count();
    println!("d = {d}, drifted coords = {:.2}%", drift * 100.0);
    println!("stage 1 — Δ (shared-seed mask diff): {n_delta} indexes ({:.3}% of d)",
        n_delta as f64 / d as f64 * 100.0);

    let codec = DeltaMaskCodec::default();
    let ctx = EncodeCtx {
        d,
        theta_k: &theta_k,
        theta_g: &theta_g,
        mask_k: &mask_k,
        mask_g: &mask_g,
        s_k: &[],
        s_g: &[],
        kappa,
        seed: round_seed,
    };
    let mut selected = codec.select_updates(&ctx);
    selected.sort_unstable();
    println!(
        "stage 2 — top-κ (κ={kappa}): kept {} of {n_delta} (KL-ranked)",
        selected.len()
    );

    let filter = deltamask::filters::BinaryFuse::<u8, 4>::build(&selected).unwrap();
    println!(
        "stage 3 — BFuse8: {} fingerprints, {:.2} bits/entry, payload {} B",
        filter.len_fingerprints(),
        filter.bits_per_entry(),
        filter.payload_bytes()
    );

    let img = png::GrayImage::from_payload(&filter.payload());
    let png_bytes = png::encode(&img);
    println!(
        "stage 4 — grayscale PNG A_k: {}×{} px, {} B ({:+.1}% vs raw payload)",
        img.width,
        img.height,
        png_bytes.len(),
        (png_bytes.len() as f64 / filter.payload_bytes() as f64 - 1.0) * 100.0
    );

    let enc = codec.encode(&ctx)?;
    println!(
        "full record: {} B ⇒ {:.4} bits-per-parameter",
        enc.bytes.len(),
        enc.bpp(d)
    );

    let dctx = DecodeCtx {
        d,
        mask_g: &mask_g,
        s_g: &[],
        seed: round_seed,
    };
    let Update::Mask(recon) = codec.decode(&enc.bytes, &dctx)? else {
        unreachable!()
    };
    let missed = (0..d)
        .filter(|&i| selected.binary_search(&(i as u64)).is_ok() && recon[i] == mask_g[i] && mask_k[i] != mask_g[i])
        .count();
    let false_flips = (0..d)
        .filter(|&i| mask_k[i] == mask_g[i] && recon[i] != mask_g[i])
        .count();
    println!(
        "stage 5 — server reconstruction: missed true updates = {missed}, \
         false flips = {false_flips} (expected ≈ d·2⁻⁸ = {:.0})",
        d as f64 / 256.0
    );
    Ok(())
}
