//! Dimension-sharded aggregation: partition the parameter space `0..d`
//! into `S` contiguous shards, each owning its own slice of the
//! aggregation state, its own participation counters (inside the slice
//! sink) and its own [`ScratchPool`], behind the same
//! `begin_round`/`absorb`/`finish_round` streaming interface the
//! single-lane [`Aggregator`] exposes.
//!
//! This is the ROADMAP's million-client seam: the server-side cost of a
//! round is an O(d) sweep per client update (the Eq. 5 pseudo-count
//! accumulation), and a single absorb thread caps throughput at one
//! socket's memory bandwidth. Splitting `d` at shard boundaries makes the
//! absorb stage embarrassingly parallel in the dimension axis — the same
//! structure FedPM-style mask aggregation has on paper, where every
//! coordinate's pseudo-count is independent of every other's.
//!
//! ## Shape
//!
//! A [`ShardedAggregator`] owns `S` **resident lane threads**, spawned
//! once at construction and parked between rounds on a per-lane control
//! channel — round t+1 reuses the threads (and each lane's sub-update
//! [`ScratchPool`]) that round t warmed up, so a view that outlives its
//! rounds reaches a cross-round zero-allocation, zero-spawn steady state
//! (the round-resident drain pipeline keeps one view per experiment).
//! Between rounds each lane parks its `(range, sink, pool)` triple on the
//! coordinating thread; `begin_round` ships every sink to its lane thread
//! together with a fresh bounded job queue and hands out a clonable
//! [`ShardRouter`]. Routing a decoded record copies each shard's
//! sub-range into a buffer leased from that shard's pool (or range-decodes
//! straight into it, see [`ShardRouter::route_decoded_ranges`]) and
//! enqueues it on the lane's queue; the lane thread absorbs sub-updates in
//! arrival order and recycles spent buffers into its own pool.
//! `finish_round` sends each lane a `Finish` marker, collects the sinks
//! back and parks the lanes again — at which point
//! [`ShardedAggregator::into_shards`] (full decomposition) or
//! [`ShardedAggregator::shard_slices`] (borrowed peek, for the resident
//! path's per-round θ_g sync) expose the slices for stitching (see
//! `fl::server::MaskServer::{adopt_shards, sync_from_shards}`).
//!
//! Abort discipline is unchanged from the per-round-spawn design: an
//! aborted round drops every per-round job-queue sender, the lane drains
//! what was already queued, hands its (mid-round) sink back *unfinished*
//! and parks — ready for the superseding `begin_round`. Dropping the
//! whole view mid-round still joins every lane thread.
//!
//! ## Why sharding preserves bitwise identity
//!
//! Every conforming [`Aggregator`] update rule is **per-coordinate**
//! (pseudo-count adds, slot-ordered FedAvg on scores), so restricting it
//! to a contiguous range commutes with running it over all of `d`: lane
//! `s` performs exactly the arithmetic the single-lane path performs on
//! coordinates `range_s`, in an equivalent order (each lane sees every
//! slot, and the [`Aggregator`] contract already requires arrival-order
//! equivalence). Stitching the slices back is a pure copy. The property
//! suite in `rust/tests/agg_shards.rs` checks bitwise identity across all
//! all 11 codecs × both pipeline modes × shard counts {1,2,3,8} under
//! adversarial arrival orders — and, for the resident path, across
//! multi-round trajectories through the same view.

use super::aggregate::Aggregator;
use crate::compress::{MaskRangeDecoder, PoolStats, ScratchPool, Update};
use crate::util::timer::Stopwatch;
use std::ops::Range;
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Sub-updates a lane's bounded queue holds before routing backpressures.
/// Memory in the decode→absorb hand-off stays O(cap · d) across all lanes
/// combined (each lane buffers `cap` sub-ranges of length ~d/S).
const LANE_QUEUE_CAP: usize = 4;

/// Partition `0..d` into `shards` contiguous, near-equal ranges (the
/// first `d % shards` ranges are one element longer). The shard count is
/// clamped to `[1, max(d, 1)]` so no lane ever owns an empty range.
///
/// ```
/// use deltamask::coordinator::shard_bounds;
/// assert_eq!(shard_bounds(7, 3), vec![0..3, 3..5, 5..7]);
/// assert_eq!(shard_bounds(6, 1), vec![0..6]);
/// assert_eq!(shard_bounds(2, 8).len(), 2); // clamped: never empty shards
/// ```
pub fn shard_bounds(d: usize, shards: usize) -> Vec<Range<usize>> {
    let s = shards.clamp(1, d.max(1));
    let base = d / s;
    let extra = d % s;
    let mut bounds = Vec::with_capacity(s);
    let mut start = 0;
    for i in 0..s {
        let len = base + usize::from(i < extra);
        bounds.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, d);
    bounds
}

/// What a lane thread hands back when its round ends (normally after
/// `Finish`, or unfinished when the round was aborted).
struct LaneReturn<A> {
    sink: A,
    absorb_secs: f64,
    finished: bool,
}

enum LaneMsg {
    /// A pre-split sub-update: absorb as-is.
    Absorb { slot: usize, update: Update },
    /// A range-decodable record: the lane runs this shard's slice of the
    /// Eq. 5 membership sweep itself (`base` is the m^{g,t-1} baseline for
    /// `range`, leased from the lane's pool; `decoder` is the record's
    /// parsed filter, shared across the S lanes), then absorbs the
    /// result. This is what makes a single huge record's *decode* sweep —
    /// not just its absorb — run on S threads.
    DecodeAbsorb {
        slot: usize,
        range: Range<usize>,
        base: Vec<f32>,
        decoder: Arc<dyn MaskRangeDecoder>,
    },
    /// Close the lane's round; `partial` finishes degraded (quorum) rounds
    /// through the slice sink's `finish_round_partial`.
    Finish { partial: bool },
}

/// One round's work package, shipped to a resident lane thread through its
/// control channel: the expected participant count, the slice sink (moved
/// onto the lane for the round's duration) and the round's bounded job
/// queue receiver.
struct LaneRound<A> {
    expected: usize,
    sink: A,
    jobs: Receiver<LaneMsg>,
}

/// One quiescent shard: its d-range, its slice sink (parked here between
/// rounds, on the lane thread while a round is in flight), its dedicated
/// sub-update buffer pool, and the handles to its resident lane thread.
struct ShardLane<A> {
    range: Range<usize>,
    sink: Option<A>,
    pool: Arc<ScratchPool>,
    /// Absorb compute seconds this lane spent in the last finished round.
    absorb_secs: f64,
    /// Control channel feeding round packages to the resident thread;
    /// dropping it shuts the thread down.
    ctrl: Option<Sender<LaneRound<A>>>,
    /// Sinks travel back here at round end (finish or abort).
    ret: Receiver<LaneReturn<A>>,
    handle: Option<JoinHandle<()>>,
}

/// The shareable per-round routing table: shard ranges, pools and lane
/// queue senders. Cloned into decode workers so they hand each decoded
/// record straight to the absorb lanes without serializing on the
/// draining thread.
#[derive(Clone)]
pub struct ShardRouter {
    lanes: Arc<[RouterLane]>,
}

struct RouterLane {
    range: Range<usize>,
    pool: Arc<ScratchPool>,
    tx: SyncSender<LaneMsg>,
}

impl ShardRouter {
    /// Split `update` at the shard boundaries and enqueue each sub-range
    /// on its shard's absorb lane (leasing the sub-buffer from that
    /// shard's pool). Blocks when a lane's bounded queue is full — that
    /// backpressure is what keeps decode from racing ahead of absorb.
    ///
    /// The caller keeps ownership of the full reconstruction buffer and
    /// should recycle it (`Update::into_vec` → the drain's `ScratchPool`)
    /// once this returns.
    pub fn route(&self, slot: usize, update: &Update) {
        for lane in self.lanes.iter() {
            let sub = match update {
                Update::Mask(v) => Update::Mask(lane.pool.take_copy(&v[lane.range.clone()])),
                Update::ScoreDelta(v) => {
                    Update::ScoreDelta(lane.pool.take_copy(&v[lane.range.clone()]))
                }
            };
            // A send can only fail if the lane exited early, which means
            // its sink panicked (a coordinator bug); the panic surfaces
            // when the lanes are joined, so it is not swallowed here.
            let _ = lane.tx.send(LaneMsg::Absorb { slot, update: sub });
        }
    }

    /// Range-restricted fan-out: hand each lane a buffer holding its
    /// slice of the m^{g,t-1} baseline (leased from that lane's pool)
    /// plus a shared handle to the record's parsed filter; **each lane
    /// thread then runs its own shard's slice of the Eq. 5 membership
    /// sweep** before absorbing it. The full `d`-length buffer is never
    /// materialized and no single thread sweeps the whole record — one
    /// huge record's decode, not just its absorb, runs on S threads.
    /// Bitwise identical to decoding fully and calling
    /// [`ShardRouter::route`] (the [`MaskRangeDecoder`] contract: range
    /// membership — false positives included — is a per-index property).
    pub fn route_decoded_ranges(
        &self,
        slot: usize,
        mask_g: &[f32],
        decoder: Arc<dyn MaskRangeDecoder>,
    ) {
        for lane in self.lanes.iter() {
            let base = lane.pool.take_copy(&mask_g[lane.range.clone()]);
            let _ = lane.tx.send(LaneMsg::DecodeAbsorb {
                slot,
                range: lane.range.clone(),
                base,
                decoder: Arc::clone(&decoder),
            });
        }
    }

    /// Number of shard lanes this router fans out to.
    pub fn shard_count(&self) -> usize {
        self.lanes.len()
    }
}

/// The routing table for one in-flight round (the resident lane threads
/// themselves live in the [`ShardLane`]s for the aggregator's lifetime).
struct RunningRound {
    router: ShardRouter,
}

/// Dimension-sharded streaming aggregation sink: `S` contiguous shards of
/// the parameter space, each with its own slice sink, participation
/// counters and [`ScratchPool`], absorbed on `S` resident lane threads
/// (spawned once, parked between rounds).
///
/// Construct it from `(range, slice sink)` pairs tiling `0..d` — for the
/// Bayesian mask server, `fl::server::MaskServer::shard_view` builds the
/// slices and `adopt_shards` stitches them back after the round. Drive it
/// either as a plain [`Aggregator`] (inline `absorb` splits each record
/// and fans it out) or through [`drain_round`](super::drain_round) /
/// [`DrainPipeline`](super::DrainPipeline) with
/// [`DrainConfig::shards`](super::DrainConfig) > 1, where the decode
/// workers route records to the lanes directly via [`ShardRouter`].
///
/// ```
/// use deltamask::compress::Update;
/// use deltamask::coordinator::Aggregator;
/// use deltamask::fl::server::MaskServer;
///
/// // Two identical servers; one aggregates the round monolithically,
/// // the other through a 3-shard view — bitwise-identical results.
/// let mut mono = MaskServer::with_theta0(8, 1.0, 0.5);
/// let mut split = mono.clone();
/// let updates = vec![
///     Update::Mask(vec![1.0, 0.0, 1.0, 1.0, 0.0, 1.0, 0.0, 0.0]),
///     Update::Mask(vec![0.0, 0.0, 1.0, 0.0, 1.0, 1.0, 1.0, 0.0]),
/// ];
/// mono.aggregate(&updates);
///
/// let mut view = split.shard_view(3);
/// view.begin_round(2);
/// for (slot, u) in updates.iter().enumerate() {
///     view.absorb(slot, u.clone());
/// }
/// view.finish_round();
/// assert_eq!(view.absorb_secs_by_shard().len(), 3);
/// split.adopt_shards(view);
///
/// assert_eq!(mono.theta_g, split.theta_g); // bitwise
/// assert_eq!(mono.s_g, split.s_g);
/// ```
pub struct ShardedAggregator<A> {
    lanes: Vec<ShardLane<A>>,
    running: Option<RunningRound>,
    /// Full decoded buffers spent by the inline `absorb` path (their
    /// shard sub-ranges already copied out), awaiting reclamation by the
    /// drain loop via [`Aggregator::reclaim_buffer`].
    spent: Vec<Vec<f32>>,
}

impl<A: Aggregator + Send + 'static> ShardedAggregator<A> {
    /// Build a sharded sink from `(range, slice sink)` pairs. The ranges
    /// must tile `0..d` contiguously in order (see [`shard_bounds`]).
    /// Spawns one resident lane thread per shard; the threads park until
    /// the first `begin_round` and are reused by every subsequent round.
    pub fn new(shards: Vec<(Range<usize>, A)>) -> Self {
        assert!(!shards.is_empty(), "at least one shard required");
        let mut expect = 0;
        for (range, _) in &shards {
            assert_eq!(
                range.start, expect,
                "shard ranges must tile 0..d contiguously"
            );
            assert!(range.end >= range.start, "inverted shard range");
            expect = range.end;
        }
        Self {
            lanes: shards
                .into_iter()
                .map(|(range, sink)| Self::spawn_lane(range, sink))
                .collect(),
            running: None,
            spent: Vec::new(),
        }
    }

    /// Spawn one resident lane thread: it loops over round packages from
    /// the control channel, absorbing each round's sub-updates and handing
    /// the sink back, until the control channel is dropped (shutdown).
    fn spawn_lane(range: Range<usize>, sink: A) -> ShardLane<A> {
        let pool = Arc::new(ScratchPool::new());
        let (ctrl_tx, ctrl_rx) = mpsc::channel::<LaneRound<A>>();
        let (ret_tx, ret_rx) = mpsc::channel::<LaneReturn<A>>();
        let lane_pool = Arc::clone(&pool);
        let handle = std::thread::spawn(move || {
            while let Ok(LaneRound {
                expected,
                mut sink,
                jobs,
            }) = ctrl_rx.recv()
            {
                sink.begin_round(expected);
                let mut absorb_secs = 0.0;
                let mut finished = false;
                while let Ok(msg) = jobs.recv() {
                    match msg {
                        LaneMsg::Absorb { slot, update } => {
                            let t = Stopwatch::new();
                            sink.absorb(slot, update);
                            while let Some(buf) = sink.reclaim_buffer() {
                                lane_pool.put(buf);
                            }
                            absorb_secs += t.elapsed_secs();
                        }
                        LaneMsg::DecodeAbsorb {
                            slot,
                            range,
                            mut base,
                            decoder,
                        } => {
                            // This shard's slice of the record's Eq. 5
                            // sweep runs here, on the lane thread, in
                            // parallel with the other shards' slices.
                            let t = Stopwatch::new();
                            decoder.decode_range(range, &mut base);
                            sink.absorb(slot, Update::Mask(base));
                            while let Some(buf) = sink.reclaim_buffer() {
                                lane_pool.put(buf);
                            }
                            absorb_secs += t.elapsed_secs();
                        }
                        LaneMsg::Finish { partial } => {
                            if partial {
                                sink.finish_round_partial();
                            } else {
                                sink.finish_round();
                            }
                            finished = true;
                            break;
                        }
                    }
                }
                // Every round sender dropped without `Finish` means the
                // round was aborted: hand the (mid-round) sink back so the
                // next `begin_round` can supersede its state, exactly like
                // an aborted serial round — then park for the next round.
                if ret_tx
                    .send(LaneReturn {
                        sink,
                        absorb_secs,
                        finished,
                    })
                    .is_err()
                {
                    return; // aggregator gone mid-teardown
                }
            }
        });
        ShardLane {
            range,
            sink: Some(sink),
            pool,
            absorb_secs: 0.0,
            ctrl: Some(ctrl_tx),
            ret: ret_rx,
            handle: Some(handle),
        }
    }

    /// Activate the resident lanes for one round and build the router.
    fn start_round(&mut self, expected: usize) {
        let mut router_lanes = Vec::with_capacity(self.lanes.len());
        for lane in &mut self.lanes {
            let (tx, rx) = mpsc::sync_channel::<LaneMsg>(LANE_QUEUE_CAP);
            let sink = lane.sink.take().expect("lane sink present between rounds");
            let round = LaneRound {
                expected,
                sink,
                jobs: rx,
            };
            if lane.ctrl.as_ref().expect("lanes alive").send(round).is_err() {
                // The resident thread is gone — it can only have panicked.
                Self::propagate_lane_death(lane);
            }
            router_lanes.push(RouterLane {
                range: lane.range.clone(),
                pool: Arc::clone(&lane.pool),
                tx,
            });
        }
        self.running = Some(RunningRound {
            router: ShardRouter {
                lanes: router_lanes.into(),
            },
        });
    }

    /// Close the in-flight round on every lane — `partial` routes to the
    /// slice sinks' `finish_round_partial` (degraded quorum rounds).
    fn finish_lanes(&mut self, partial: bool) {
        let RunningRound { router } = self
            .running
            .take()
            .expect("ShardedAggregator::finish_round called before begin_round");
        // Lane queues are FIFO and every routed sub-update was enqueued
        // before its completion was acknowledged, so `Finish` lands after
        // the round's full absorb set on every lane.
        for lane in router.lanes.iter() {
            let _ = lane.tx.send(LaneMsg::Finish { partial });
        }
        drop(router);
        let finished = self.collect_round();
        assert!(finished, "a shard lane exited before Finish");
    }
}

impl<A> ShardedAggregator<A> {
    /// Number of shards (== absorb lanes).
    pub fn shard_count(&self) -> usize {
        self.lanes.len()
    }

    /// Total dimensionality the shards tile.
    pub fn d(&self) -> usize {
        self.lanes.last().map(|l| l.range.end).unwrap_or(0)
    }

    /// The shard ranges, in order.
    pub fn bounds(&self) -> Vec<Range<usize>> {
        self.lanes.iter().map(|l| l.range.clone()).collect()
    }

    /// Absorb compute seconds each lane spent in the last finished round,
    /// indexed by shard. A lopsided split flags dimension imbalance
    /// (e.g. one shard owning all the dense payload coordinates).
    pub fn absorb_secs_by_shard(&self) -> Vec<f64> {
        self.lanes.iter().map(|l| l.absorb_secs).collect()
    }

    /// Aggregate lease counters across every lane's sub-update pool. For a
    /// view that outlives its rounds, `misses` freezing after the warm-up
    /// round is the observable cross-round zero-allocation property.
    pub fn lane_pool_stats(&self) -> PoolStats {
        self.lanes
            .iter()
            .fold(PoolStats::default(), |acc, l| acc.merged(l.pool.stats()))
    }

    /// Borrow the parked `(range, slice sink)` pairs — `None` while a
    /// round is in flight (the sinks are on their lane threads). The
    /// resident drain path uses this to refresh the global broadcast
    /// state between rounds without consuming the view.
    pub fn shard_slices(&self) -> Option<Vec<(Range<usize>, &A)>> {
        if self.running.is_some() {
            return None;
        }
        self.lanes
            .iter()
            .map(|l| l.sink.as_ref().map(|s| (l.range.clone(), s)))
            .collect()
    }

    /// Tear down an in-flight round without finishing it: drop the lane
    /// job queues, wait for every lane to hand its (mid-round) sink back
    /// and park. Safe to call at any time; a no-op between rounds.
    ///
    /// Callers must ensure no external [`ShardRouter`] clone outlives this
    /// call (the drain paths join their decode workers first) — a live
    /// clone would keep a lane's job queue open and stall the hand-back.
    pub fn abort_round(&mut self) {
        let Some(RunningRound { router }) = self.running.take() else {
            return;
        };
        drop(router); // all round senders gone → lanes drain, return, park
        self.collect_round();
    }

    /// Decompose into `(range, slice sink)` pairs for stitching back into
    /// the global state. Aborts any round still in flight and shuts the
    /// resident lane threads down first.
    pub fn into_shards(mut self) -> Vec<(Range<usize>, A)> {
        self.abort_round();
        self.shutdown_lanes();
        std::mem::take(&mut self.lanes)
            .into_iter()
            .map(|lane| {
                (
                    lane.range,
                    lane.sink.expect("lane sink present after abort/finish"),
                )
            })
            .collect()
    }

    /// Collect each lane's round return, parking the sinks; propagates
    /// lane panics. Returns whether every lane saw `Finish`.
    fn collect_round(&mut self) -> bool {
        let mut all_finished = true;
        for lane in &mut self.lanes {
            match lane.ret.recv() {
                Ok(ret) => {
                    lane.sink = Some(ret.sink);
                    lane.absorb_secs = ret.absorb_secs;
                    all_finished &= ret.finished;
                }
                Err(_) => Self::propagate_lane_death(lane),
            }
        }
        all_finished
    }

    /// Drop the control channels and join the resident threads; propagates
    /// a lane panic. Must not be called with a round in flight.
    fn shutdown_lanes(&mut self) {
        for lane in &mut self.lanes {
            lane.ctrl = None;
        }
        for lane in &mut self.lanes {
            if let Some(handle) = lane.handle.take() {
                if let Err(panic) = handle.join() {
                    std::panic::resume_unwind(panic);
                }
            }
        }
    }

    /// A lane's channel disconnected outside shutdown: the resident thread
    /// died, which only a sink panic can cause — join it and re-raise.
    fn propagate_lane_death(lane: &mut ShardLane<A>) -> ! {
        match lane.handle.take() {
            Some(handle) => match handle.join() {
                Err(panic) => std::panic::resume_unwind(panic),
                Ok(()) => unreachable!("lane exited without panicking while in use"),
            },
            None => panic!("shard lane thread missing"),
        }
    }
}

impl<A: Aggregator + Send + 'static> Aggregator for ShardedAggregator<A> {
    fn begin_round(&mut self, expected: usize) {
        // A round left in flight by an aborted drain is superseded, the
        // same tolerance the single-lane sinks give repeated begins.
        self.abort_round();
        self.spent.clear();
        self.start_round(expected);
    }

    /// Inline reference path: split the record at the shard boundaries on
    /// the calling thread and fan the pieces out to the absorb lanes. The
    /// routed drain (`DrainConfig::shards > 1`) bypasses this and calls
    /// [`ShardRouter::route`] from the decode workers instead.
    fn absorb(&mut self, slot: usize, update: Update) {
        assert_eq!(update.len(), self.d(), "update dimensionality mismatch");
        let running = self
            .running
            .as_ref()
            .expect("ShardedAggregator::absorb called before begin_round");
        running.router.route(slot, &update);
        // Sub-ranges are copied out; the full buffer is spent and flows
        // back to the drain's pool via `reclaim_buffer`.
        self.spent.push(update.into_vec());
    }

    fn finish_round(&mut self) {
        self.finish_lanes(false);
    }

    fn finish_round_partial(&mut self) {
        self.finish_lanes(true);
    }

    fn reclaim_buffer(&mut self) -> Option<Vec<f32>> {
        self.spent.pop()
    }

    fn shard_router(&self) -> Option<ShardRouter> {
        self.running.as_ref().map(|r| r.router.clone())
    }

    fn abort_round(&mut self) {
        ShardedAggregator::abort_round(self);
    }
}

impl<A> Drop for ShardedAggregator<A> {
    /// Dropping mid-round (e.g. the drain bailed on a decode error and
    /// the caller discards the view) still quiesces and joins every
    /// resident lane thread. Lane panics are swallowed here (double
    /// panics abort); the in-use paths re-raise them instead.
    fn drop(&mut self) {
        if let Some(RunningRound { router }) = self.running.take() {
            drop(router);
            for lane in &mut self.lanes {
                let _ = lane.ret.recv();
            }
        }
        for lane in &mut self.lanes {
            lane.ctrl = None;
        }
        for lane in &mut self.lanes {
            if let Some(handle) = lane.handle.take() {
                let _ = handle.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Per-lane spy sink recording what it absorbed. It releases every
    /// spent sub-buffer through `reclaim_buffer` (like `MaskServer` does),
    /// so the lane pools can demonstrate cross-round reuse.
    #[derive(Default)]
    struct LaneSpy {
        d: usize,
        begun: Vec<usize>,
        absorbed: Vec<(usize, Vec<f32>)>,
        spent: Vec<Vec<f32>>,
        finished: usize,
        finished_partial: usize,
    }

    impl Aggregator for LaneSpy {
        fn begin_round(&mut self, expected: usize) {
            self.begun.push(expected);
        }

        fn absorb(&mut self, slot: usize, update: Update) {
            assert_eq!(update.len(), self.d);
            let v = update.into_vec();
            self.absorbed.push((slot, v.clone()));
            self.spent.push(v);
        }

        fn finish_round(&mut self) {
            self.finished += 1;
        }

        fn finish_round_partial(&mut self) {
            self.finished += 1;
            self.finished_partial += 1;
        }

        fn reclaim_buffer(&mut self) -> Option<Vec<f32>> {
            self.spent.pop()
        }
    }

    fn spy_shards(d: usize, shards: usize) -> ShardedAggregator<LaneSpy> {
        ShardedAggregator::new(
            shard_bounds(d, shards)
                .into_iter()
                .map(|r| {
                    let spy = LaneSpy {
                        d: r.len(),
                        ..Default::default()
                    };
                    (r, spy)
                })
                .collect(),
        )
    }

    #[test]
    fn bounds_tile_the_space() {
        assert_eq!(shard_bounds(10, 4), vec![0..3, 3..6, 6..8, 8..10]);
        assert_eq!(shard_bounds(3, 3), vec![0..1, 1..2, 2..3]);
        assert_eq!(shard_bounds(5, 1), vec![0..5]);
        // Clamping: more shards than dimensions never yields empty lanes.
        assert_eq!(shard_bounds(2, 5), vec![0..1, 1..2]);
        assert_eq!(shard_bounds(0, 3), vec![0..0]);
        for (d, s) in [(1031, 8), (64, 7), (100, 100)] {
            let bounds = shard_bounds(d, s);
            assert_eq!(bounds.first().unwrap().start, 0);
            assert_eq!(bounds.last().unwrap().end, d);
            for w in bounds.windows(2) {
                assert_eq!(w[0].end, w[1].start, "d={d} s={s}");
                assert!(!w[0].is_empty());
            }
        }
    }

    #[test]
    fn inline_absorb_splits_at_shard_boundaries() {
        let d = 10;
        let mut agg = spy_shards(d, 3); // ranges 0..4, 4..7, 7..10
        agg.begin_round(2);
        let u0: Vec<f32> = (0..d).map(|i| i as f32).collect();
        agg.absorb(0, Update::Mask(u0.clone()));
        agg.absorb(1, Update::ScoreDelta(u0.iter().map(|v| -v).collect()));
        // Spent full buffers flow back through reclaim.
        assert!(agg.reclaim_buffer().is_some());
        assert!(agg.reclaim_buffer().is_some());
        assert!(agg.reclaim_buffer().is_none());
        agg.finish_round();
        let timings = agg.absorb_secs_by_shard();
        assert_eq!(timings.len(), 3);
        let shards = agg.into_shards();
        assert_eq!(shards.len(), 3);
        for (range, spy) in shards {
            assert_eq!(spy.begun, vec![2]);
            assert_eq!(spy.finished, 1);
            assert_eq!(spy.absorbed.len(), 2);
            let (slot0, sub0) = &spy.absorbed[0];
            assert_eq!(*slot0, 0);
            assert_eq!(sub0, &u0[range.clone()].to_vec(), "{range:?}");
            let (slot1, sub1) = &spy.absorbed[1];
            assert_eq!(*slot1, 1);
            assert_eq!(sub1.len(), range.len());
        }
    }

    #[test]
    fn abort_round_parks_unfinished_lanes_for_reuse() {
        let mut agg = spy_shards(6, 2);
        agg.begin_round(3);
        agg.absorb(0, Update::Mask(vec![1.0; 6]));
        agg.abort_round(); // two updates never arrive
        assert!(agg.shard_router().is_none(), "no round in flight");
        assert!(agg.shard_slices().is_some(), "sinks parked after abort");
        // Lanes were recovered mid-round, unfinished — and can be reused.
        agg.begin_round(1);
        assert!(agg.shard_slices().is_none(), "sinks on lanes mid-round");
        agg.absorb(0, Update::Mask(vec![0.0; 6]));
        agg.finish_round();
        for (_, spy) in agg.into_shards() {
            assert_eq!(spy.finished, 1, "superseding round completed");
            assert_eq!(spy.absorbed.len(), 2, "one absorb per round attempt");
        }
    }

    #[test]
    fn resident_lanes_survive_many_rounds_and_reuse_pools() {
        // The persistence property the round-resident pipeline builds on:
        // the same S lane threads (and their pools) serve every round.
        let d = 8;
        let mut agg = spy_shards(d, 2);
        for round in 0..5 {
            agg.begin_round(2);
            for slot in 0..2 {
                agg.absorb(slot, Update::Mask(vec![round as f32; d]));
                while agg.reclaim_buffer().is_some() {}
            }
            agg.finish_round();
        }
        let stats = agg.lane_pool_stats();
        // 5 rounds × 2 slots × 2 lanes = 20 sub-leases total; only the
        // first round's in-flight peak can miss, every later lease is a
        // pool hit because the lane pools persist across rounds.
        assert_eq!(stats.hits + stats.misses, 20, "{stats:?}");
        assert!(
            stats.misses <= 2 * (LANE_QUEUE_CAP as u64 + 2),
            "lane pools must be reused across rounds: {stats:?}"
        );
        for (_, spy) in agg.into_shards() {
            assert_eq!(spy.begun.len(), 5);
            assert_eq!(spy.finished, 5);
            assert_eq!(spy.absorbed.len(), 10);
        }
    }

    #[test]
    fn router_fans_out_from_foreign_threads() {
        let d = 8;
        let mut agg = spy_shards(d, 2);
        agg.begin_round(4);
        let router = agg.shard_router().expect("round in flight");
        std::thread::scope(|scope| {
            for w in 0..2 {
                let router = router.clone();
                scope.spawn(move || {
                    for slot in [w, w + 2] {
                        let v: Vec<f32> = (0..d).map(|i| (slot * 10 + i) as f32).collect();
                        router.route(slot, &Update::Mask(v));
                    }
                });
            }
        });
        drop(router);
        agg.finish_round();
        for (range, spy) in agg.into_shards() {
            assert_eq!(spy.absorbed.len(), 4);
            for (slot, sub) in &spy.absorbed {
                let expect: Vec<f32> = range.clone().map(|i| (slot * 10 + i) as f32).collect();
                assert_eq!(sub, &expect, "slot {slot} range {range:?}");
            }
        }
    }

    #[test]
    fn route_decoded_ranges_matches_full_split() {
        // Range-restricted routing (the sweep runs on each lane thread)
        // ≡ full-decode-then-split, per lane.
        struct FlipAll;
        impl MaskRangeDecoder for FlipAll {
            fn decode_range(&self, range: Range<usize>, mask: &mut [f32]) {
                // "Member" at every even index.
                for (j, m) in mask.iter_mut().enumerate() {
                    if (range.start + j) % 2 == 0 {
                        *m = 1.0 - *m;
                    }
                }
            }
        }
        let d = 9;
        let mask_g: Vec<f32> = (0..d).map(|i| (i % 3 == 0) as u32 as f32).collect();
        let mut agg = spy_shards(d, 3);
        agg.begin_round(1);
        let router = agg.shard_router().unwrap();
        router.route_decoded_ranges(0, &mask_g, Arc::new(FlipAll));
        drop(router);
        agg.finish_round();
        // Oracle: full reconstruction then split at shard boundaries.
        let mut full = mask_g.clone();
        FlipAll.decode_range(0..d, &mut full);
        for (range, spy) in agg.into_shards() {
            assert_eq!(spy.absorbed.len(), 1);
            assert_eq!(spy.absorbed[0].1, full[range.clone()].to_vec(), "{range:?}");
        }
    }

    #[test]
    fn partial_finish_reaches_every_lane() {
        let mut agg = spy_shards(6, 3);
        agg.begin_round(3);
        agg.absorb(0, Update::Mask(vec![1.0; 6]));
        agg.absorb(2, Update::Mask(vec![0.0; 6]));
        // A quorum-degraded round: slot 1 never arrives.
        agg.finish_round_partial();
        // The view stays reusable after a degraded round.
        agg.begin_round(1);
        agg.absorb(0, Update::Mask(vec![1.0; 6]));
        agg.finish_round();
        for (_, spy) in agg.into_shards() {
            assert_eq!(spy.finished, 2);
            assert_eq!(spy.finished_partial, 1);
            assert_eq!(spy.absorbed.len(), 3);
        }
    }

    #[test]
    fn drop_mid_round_joins_lanes() {
        let mut agg = spy_shards(4, 2);
        agg.begin_round(2);
        agg.absorb(0, Update::Mask(vec![1.0; 4]));
        drop(agg); // must not hang or leak a blocked lane thread
    }
}
