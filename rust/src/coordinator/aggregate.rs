//! The server-side round drain: pull encoded updates off a [`Transport`]
//! and feed an [`Aggregator`] — per-arrival (streaming) or behind the
//! full-round barrier (batch), decoded inline or fanned out to a pool of
//! decode workers ([`DrainConfig`]). This is the decode→aggregate pipeline
//! the runner used to hard-wire inline; it is generic over both the
//! transport and the aggregation rule.
//!
//! ## Sharded decode
//!
//! With `DrainConfig::workers > 1` the drain splits into two stages:
//!
//! * **decode stage** — N scoped worker threads pull `(slot, Encoded)`
//!   records off a shared queue and run [`UpdateCodec::decode_pooled`]
//!   against the round plan's broadcast snapshot, leasing output buffers
//!   from the shared [`ScratchPool`];
//! * **absorb stage** — the draining thread folds finished decodes into the
//!   aggregator as they complete and recycles the spent buffers back into
//!   the pool.
//!
//! Decoding is per-record deterministic (the context is an immutable
//! round-start snapshot) and conforming aggregators are arrival-order
//! equivalent (see the [`Aggregator`] contract), so the sharded drain is
//! **bitwise identical** to the serial path — property-tested across all 11
//! codecs, both pipeline modes and many worker counts in
//! `rust/tests/decode_workers.rs`. The results channel is bounded, so at
//! most O(workers · d) decoded floats sit in the decode→absorb hand-off no
//! matter how many arrivals pile up; pending arrivals queue in their
//! compressed form. (The *aggregator* may buffer more behind that
//! hand-off: `MaskServer`'s delta-family reorder window holds decoded
//! out-of-order updates until their slot comes up — worst case O(K · d) —
//! and sharded completion order makes reordering the norm, not the
//! exception. Mask-family absorbs spend their buffer immediately, so the
//! O(workers · d) bound is end-to-end for that family only.)
//!
//! ## Sharded absorb
//!
//! `DrainConfig::shards > 1` additionally shards the **absorb** stage in
//! the dimension axis: the aggregator must be a
//! [`ShardedAggregator`](super::ShardedAggregator), whose S absorb lanes
//! each own a contiguous `d`-range of the aggregation state. Each decoded
//! record is split at the shard boundaries and handed to the lanes through
//! the aggregator's [`ShardRouter`] — by the decode workers themselves
//! when `workers > 1` (so one huge record no longer serializes on a single
//! absorb thread), or by the draining thread when decoding is inline. The
//! routed drain is bitwise identical to both the serial and the
//! single-lane sharded-decode paths; `rust/tests/agg_shards.rs`
//! property-tests that across every codec, both pipeline modes and shard
//! counts {1, 2, 3, 8}. The operator-facing guide to how `--pipeline`,
//! `--decode-workers` and `--agg-shards` compose is `docs/SCALING.md`.
//!
//! ## Fault tolerance
//!
//! Every drain path admits wire messages through one shared [`RoundGate`]:
//! the first well-formed record per `(round, slot)` wins, and duplicates,
//! stale-round replays, out-of-range slots and in-band `Payload::Failed`
//! reports are **counted and dropped** ([`FaultCounters`]) instead of
//! aborting the round or corrupting aggregation state. Round completion is
//! governed by a [`DrainPolicy`]: with `quorum < 1.0` the round finishes —
//! flagged `degraded` in the [`DrainReport`] — once the uplink closes (or
//! the `deadline_ms` budget expires) with at least `⌈quorum · K⌉` records
//! absorbed, instead of failing because a straggler never reported; with
//! the strict default (quorum 1.0, no deadline, abort on decode error) the
//! behaviour and the aggregate are bit-identical to the fault-oblivious
//! drain. Degraded rounds finish through
//! [`Aggregator::finish_round_partial`], and the quorum verdict is taken
//! on records actually *absorbed* — so decode failures skipped under
//! [`OnDecodeError::Skip`] also count against the quorum. The
//! deterministic chaos harness that exercises all of this is
//! [`ChaosTransport`](super::ChaosTransport) + `rust/tests/churn.rs`.

use super::round::RoundPlan;
use super::shard::ShardRouter;
use super::transport::{Payload, RecvOutcome, Transport, WireMessage};
use super::PipelineMode;
use crate::compress::{Encoded, PoolStats, ScratchPool, Update, UpdateCodec};
use crate::util::timer::Stopwatch;
use anyhow::{anyhow, bail, Result};
use std::collections::VecDeque;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Streaming aggregation sink: a round is `begin_round(K)` → K×`absorb` →
/// `finish_round`. Implemented by `fl::server::MaskServer`; any other sink
/// (a sharded server, a test spy) plugs in the same way.
///
/// Contract (see `MaskServer` for the reference semantics): `absorb` must
/// accept participant slots in any arrival order and produce state
/// equivalent to slot-ordered application; `finish_round` publishes the new
/// global state. The sharded drain relies on this contract — decode workers
/// complete out of order, so a sink that silently depended on slot-ordered
/// `absorb` calls would diverge once `workers > 1`.
pub trait Aggregator {
    fn begin_round(&mut self, expected: usize);
    fn absorb(&mut self, slot: usize, update: Update);
    fn finish_round(&mut self);

    /// Hand back an update buffer whose contents have been folded into the
    /// aggregator state (mask-family absorbs spend their buffer
    /// immediately; delta-family reorder windows release them in slot
    /// order). The drain loop feeds these into its [`ScratchPool`], closing
    /// the zero-allocation decode cycle. Default: nothing to reclaim.
    fn reclaim_buffer(&mut self) -> Option<Vec<f32>> {
        None
    }

    /// For dimension-sharded sinks
    /// ([`ShardedAggregator`](super::ShardedAggregator)): the clonable
    /// router the drain uses to hand each decoded record straight to the
    /// per-shard absorb lanes. Live only between `begin_round` and
    /// `finish_round`. Single-lane sinks return `None` (the default) and
    /// the drain absorbs on the draining thread instead.
    fn shard_router(&self) -> Option<ShardRouter> {
        None
    }

    /// Abort an in-flight round after a drain error: tear down any
    /// per-shard absorb lanes and leave the sink safe to reuse or drop.
    /// Mid-round aggregation state may be partial — as with an aborted
    /// serial round, the next `begin_round` supersedes it. Default: no-op
    /// (single-lane sinks hold no threads).
    fn abort_round(&mut self) {}

    /// Finish a **degraded** round: publish new global state from however
    /// many records were actually absorbed — a quorum of
    /// `begin_round(K)`'s announced count, not necessarily all of it.
    /// Sinks whose `finish_round` asserts full participation must
    /// override this (see `MaskServer`, which also flushes its
    /// delta-family reorder window in ascending slot order so the result
    /// stays arrival-order invariant); the default delegates to
    /// [`finish_round`](Self::finish_round) for sinks that already
    /// tolerate partial rounds.
    fn finish_round_partial(&mut self) {
        self.finish_round();
    }

    /// A sticky absorb-lane fault, if any — a remote shard lane whose
    /// socket died or whose worker broke protocol
    /// (see `ShardedAggregator` and `RemoteShardLane` in
    /// [`super::shard`]). Lane faults are deliberately out-of-band: the
    /// lane keeps draining its job queue so routing never blocks, and the
    /// drain checks this before *and after* settling so a faulted round
    /// aborts instead of publishing half-absorbed global state. Default:
    /// `None` (single-lane and all-local sinks cannot fault).
    fn lane_fault(&self) -> Option<String> {
        None
    }
}

/// Abort and bail if the aggregator reports a lane fault. Called by every
/// drain shape right before settling (so a round that lost a shard lane
/// mid-absorb never finishes) and right after finishing (so a fault during
/// the finish exchange itself — the slice-return leg — surfaces on this
/// round, not the next). `abort_round` after a completed finish is a
/// no-op, so the post-finish call is safe on both outcomes.
pub(super) fn bail_on_lane_fault<A: Aggregator + ?Sized>(agg: &mut A) -> Result<()> {
    if let Some(fault) = agg.lane_fault() {
        agg.abort_round();
        bail!("shard lane fault: {fault}");
    }
    Ok(())
}

/// What to do when a record fails to decode mid-round.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OnDecodeError {
    /// Abort the round with an error (the strict default — a malformed
    /// record is evidence of a bug somewhere, surface it).
    #[default]
    Abort,
    /// Count the record as corrupt, skip it, and keep draining; the slot
    /// then counts against the quorum like any other missing record.
    Skip,
}

impl OnDecodeError {
    pub fn as_str(&self) -> &'static str {
        match self {
            OnDecodeError::Abort => "abort",
            OnDecodeError::Skip => "skip",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "abort" => Ok(OnDecodeError::Abort),
            "skip" => Ok(OnDecodeError::Skip),
            other => bail!("unknown on-decode-error policy `{other}` (expected abort|skip)"),
        }
    }
}

/// Round completion policy: when is a drained round *done*?
///
/// The strict default — quorum 1.0, no deadline, abort on decode error —
/// reproduces the fault-oblivious drain exactly: every planned record must
/// arrive and decode. Relaxing `quorum` lets the round finish degraded
/// over whoever showed up once the uplink closes; adding a deadline bounds
/// how long the server waits for stragglers at all. The quorum is a
/// **floor, not an early exit**: the drain keeps receiving until intake
/// genuinely ends (every sender gone, or the deadline passes), so which
/// cohort survives never depends on thread scheduling or arrival order —
/// the property the degradation-correctness tests in
/// `rust/tests/churn.rs` pin down.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DrainPolicy {
    /// Fraction of `RoundPlan::expected()` records that must be absorbed
    /// for the round to complete (`⌈quorum · K⌉`, clamped to `[1, K]`).
    pub quorum: f64,
    /// Wall-clock budget for the drain in milliseconds; `0` = no deadline
    /// (wait until every sender handle drops).
    pub deadline_ms: u64,
    /// Decode-failure handling (see [`OnDecodeError`]).
    pub on_decode_error: OnDecodeError,
}

impl Default for DrainPolicy {
    fn default() -> Self {
        Self {
            quorum: 1.0,
            deadline_ms: 0,
            on_decode_error: OnDecodeError::Abort,
        }
    }
}

impl DrainPolicy {
    /// The strict reference policy (everyone reports, no deadline, abort
    /// on decode error).
    pub fn strict() -> Self {
        Self::default()
    }

    /// Absolute number of absorbed records required for `expected`
    /// planned participants. At least one record is always required.
    pub fn quorum_count(&self, expected: usize) -> usize {
        (((self.quorum * expected as f64).ceil()) as usize).clamp(1.min(expected), expected)
    }

    fn deadline(&self) -> Option<Instant> {
        (self.deadline_ms > 0).then(|| Instant::now() + Duration::from_millis(self.deadline_ms))
    }
}

/// Per-round admission/fault accounting. Every rejected message is counted
/// here rather than silently swallowed or fatally surfaced, so churn
/// experiments get honest numbers and reproducibility tests can assert
/// exact counter values per chaos seed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Messages pulled off the transport during the round.
    pub received: u64,
    /// Records admitted (first well-formed record per slot).
    pub accepted: u64,
    /// Extra copies of an already-admitted `(round, slot)` — replay or
    /// duplicate delivery; first record wins.
    pub duplicates: u64,
    /// Replays carrying a different round number than the live round.
    pub stale: u64,
    /// Slot indices outside the round plan (buggy or malicious client).
    pub bad_slot: u64,
    /// In-band `Payload::Failed` reports (client died mid-round).
    pub failed: u64,
    /// Undecodable records skipped under [`OnDecodeError::Skip`].
    pub corrupt: u64,
    /// Current-round records that arrived after the deadline expired
    /// (found by the non-blocking late sweep, not absorbed).
    pub late: u64,
    /// Planned slots with no absorbed record when the round finished.
    pub missing: u64,
}

/// Server-side decode→absorb scheduling for one drained round: the
/// pipeline mode, the number of decode worker threads and the number of
/// dimension shards for the absorb stage.
///
/// `workers == 1` decodes inline on the draining thread (the serial
/// reference path); `workers > 1` shards decoding across that many scoped
/// threads; `workers == 0` resolves to one worker per available core.
/// `shards == 1` keeps the single absorb lane; `shards > 1` requires a
/// dimension-sharded aggregator
/// ([`ShardedAggregator`](super::ShardedAggregator)) and splits every
/// decoded record across that many absorb lanes at shard boundaries;
/// `shards == 0` resolves to one shard per available core. All settings
/// produce bitwise-identical aggregator state.
///
/// ```
/// use deltamask::coordinator::{DrainConfig, PipelineMode};
/// let serial = DrainConfig::serial(PipelineMode::Streaming);
/// assert_eq!((serial.resolved_workers(), serial.resolved_shards()), (1, 1));
/// let decode_sharded = DrainConfig::new(PipelineMode::Batch, 4);
/// assert_eq!(decode_sharded.resolved_workers(), 4);
/// assert_eq!(decode_sharded.resolved_shards(), 1);
/// let dim_sharded = DrainConfig::sharded(PipelineMode::Streaming, 4, 8);
/// assert_eq!(dim_sharded.resolved_shards(), 8);
/// assert!(DrainConfig::sharded(PipelineMode::Streaming, 0, 0).resolved_shards() >= 1);
/// ```
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DrainConfig {
    /// Batch (full-round barrier) vs streaming (per-arrival absorb).
    pub mode: PipelineMode,
    /// Decode worker threads (1 = serial, 0 = one per available core).
    pub workers: usize,
    /// Dimension shards for the absorb stage (`--agg-shards N`): 1 = the
    /// single-lane reference path, N > 1 = that many parallel absorb
    /// lanes fed through a [`ShardRouter`], 0 = one shard per core.
    pub shards: usize,
    /// Round completion policy (quorum / deadline / decode-error
    /// handling). The default is strict — see [`DrainPolicy`].
    pub policy: DrainPolicy,
}

impl DrainConfig {
    pub fn new(mode: PipelineMode, workers: usize) -> Self {
        Self {
            mode,
            workers,
            shards: 1,
            policy: DrainPolicy::default(),
        }
    }

    /// The single-threaded reference path (`workers = 1`, `shards = 1`).
    pub fn serial(mode: PipelineMode) -> Self {
        Self {
            mode,
            workers: 1,
            shards: 1,
            policy: DrainPolicy::default(),
        }
    }

    /// Fully-specified drain: `workers` decode threads feeding `shards`
    /// absorb lanes.
    pub fn sharded(mode: PipelineMode, workers: usize, shards: usize) -> Self {
        Self {
            mode,
            workers,
            shards,
            policy: DrainPolicy::default(),
        }
    }

    /// Builder-style completion-policy override.
    pub fn with_policy(mut self, policy: DrainPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Effective worker count: `0` resolves to the available parallelism.
    pub fn resolved_workers(&self) -> usize {
        match self.workers {
            0 => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
            n => n,
        }
    }

    /// Effective shard count: `0` resolves to the available parallelism.
    pub fn resolved_shards(&self) -> usize {
        match self.shards {
            0 => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
            n => n,
        }
    }
}

/// Deterministic per-slot accounting from one drained round. Kept per-slot
/// (not running sums) so callers can reduce in slot order — f64 addition is
/// order-sensitive and arrival order is not deterministic.
#[derive(Clone, Debug)]
pub struct DrainReport {
    /// Mean local training loss, by participant slot.
    pub loss_by_slot: Vec<f64>,
    /// Client-side encode seconds, by participant slot.
    pub enc_by_slot: Vec<f64>,
    /// Total server-side decode compute seconds, summed over records. For
    /// the serial path this equals the decode wall time; for the sharded
    /// path it is the aggregate compute across workers (wall time is lower
    /// — that gap is the speedup `benches/hotpaths.rs` tracks). Routing
    /// hand-offs and lane backpressure are never on this clock, and for
    /// range-split records (dimension-sharded drain, range-capable codec)
    /// only the parse/validate/filter-rebuild runs on the decode thread —
    /// the per-shard membership sweeps run on the absorb lanes and are
    /// accounted in their `absorb_secs_by_shard` timings.
    pub dec_secs: f64,
    /// Decode compute seconds attributed to each worker, indexed by worker
    /// id (length = resolved worker count; the serial path reports one
    /// entry). Sums to `dec_secs` up to f64 reduction order.
    pub dec_by_worker: Vec<f64>,
    /// Decode-buffer pool accounting for this round (the pool handed to
    /// the drain; shard-lane pools are reported by the aggregator). A
    /// pool that outlives its rounds shows `misses` at zero once warm —
    /// the observable cross-round zero-allocation property.
    pub pool: PoolStats,
    /// Admission/fault accounting (see [`FaultCounters`]). All zeros on a
    /// fault-free round.
    pub faults: FaultCounters,
    /// Whether the quorum was met by absorbed records. Always `true` on a
    /// returned report — a missed quorum is an error — but carried so the
    /// metrics emission states it explicitly.
    pub quorum_met: bool,
    /// `true` when the round finished with fewer than the planned number
    /// of absorbed records (partial participation).
    pub degraded: bool,
}

impl DrainReport {
    pub(crate) fn new(expected: usize, workers: usize) -> Self {
        Self {
            loss_by_slot: vec![0.0; expected],
            enc_by_slot: vec![0.0; expected],
            dec_secs: 0.0,
            dec_by_worker: vec![0.0; workers],
            pool: PoolStats::default(),
            faults: FaultCounters::default(),
            quorum_met: true,
            degraded: false,
        }
    }

    pub fn total_loss(&self) -> f64 {
        self.loss_by_slot.iter().sum()
    }

    pub fn total_enc_secs(&self) -> f64 {
        self.enc_by_slot.iter().sum()
    }
}

/// Drain one round's `plan.expected()` updates from `transport`, decode
/// them against the plan's broadcast snapshot, and drive `agg` per `cfg`.
///
/// Streaming: decode→absorb per arrival (the aggregator holds O(d) state).
/// Batch: buffer every payload, then decode + absorb behind the barrier —
/// the seed's reference behaviour. With `cfg.workers > 1` decoding is
/// sharded across a worker pool in either mode, and with `cfg.shards > 1`
/// the absorb stage is additionally sharded across the aggregator's
/// per-dimension lanes (`agg` must then be a
/// [`ShardedAggregator`](super::ShardedAggregator); see the module docs).
/// Every combination produces bitwise identical aggregator state (see
/// `fl::server` module docs).
///
/// Decoding draws its output buffers from `pool` and the aggregator's
/// spent buffers flow back into it after every absorb, so a pool that
/// outlives the round (the runner owns one per experiment) makes
/// steady-state decode allocation-free.
///
/// Admission and completion are governed by `cfg.policy` (see
/// [`DrainPolicy`] and the module docs): duplicates, stale-round replays,
/// bad slots and in-band client failures are counted and dropped; the
/// round errors only when intake ends (uplink closed or deadline expired)
/// below the quorum, or — under the default
/// [`OnDecodeError::Abort`] — when a record fails to decode. In the
/// sharded path an aborting decode error surfaced by any worker tears the
/// round down cleanly (pending work dropped, every worker joined,
/// [`Aggregator::abort_round`] called) before the error is returned.
///
/// ```
/// use deltamask::compress::{self, ScratchPool};
/// use deltamask::coordinator::{
///     drain_round, ChannelTransport, DrainConfig, Payload, PipelineMode, RoundEngine,
///     WireMessage,
/// };
/// use deltamask::fl::server::MaskServer;
/// use deltamask::model::sample_mask_seeded;
///
/// // A 2-client round: plan it, encode each client's sampled mask...
/// let d = 64;
/// let theta = vec![0.5f32; d];
/// let s = vec![0.0f32; d];
/// let plan = RoundEngine::new(7, 2, 1.0, 0.8, 0.25, 1).plan(0, &theta, &s);
/// let codec = compress::by_name("fedpm").unwrap();
/// let (mut transport, sender) = ChannelTransport::new();
/// for slot in 0..plan.expected() {
///     let mut mask_k = Vec::new();
///     sample_mask_seeded(&plan.theta_g, plan.client_seed(slot), &mut mask_k);
///     let enc = codec
///         .encode(&plan.encode_ctx(slot, &plan.theta_g, &mask_k, &[]))
///         .unwrap();
///     sender
///         .send(WireMessage {
///             round: 0,
///             client_id: plan.participants[slot],
///             slot,
///             payload: Payload::Update(enc),
///             enc_secs: 0.0,
///             loss: 0.5,
///         })
///         .unwrap();
/// }
/// drop(sender); // all clients reported; the uplink closes
///
/// // ...then drain them into the Bayesian server on 2 decode workers.
/// let mut server = MaskServer::with_theta0(d, 1.0, 0.5);
/// let pool = ScratchPool::new();
/// let report = drain_round(
///     &mut transport,
///     &plan,
///     codec.as_ref(),
///     &mut server,
///     DrainConfig::new(PipelineMode::Streaming, 2),
///     &pool,
/// )
/// .unwrap();
/// assert_eq!(report.loss_by_slot, vec![0.5, 0.5]);
/// assert_eq!(report.dec_by_worker.len(), 2);
/// ```
pub fn drain_round(
    transport: &mut dyn Transport,
    plan: &RoundPlan,
    codec: &dyn UpdateCodec,
    agg: &mut dyn Aggregator,
    cfg: DrainConfig,
    pool: &ScratchPool,
) -> Result<DrainReport> {
    let workers = cfg.resolved_workers();
    let pool_before = pool.stats();
    let mut report = if cfg.resolved_shards() > 1 {
        drain_shard_routed(transport, plan, codec, agg, cfg.mode, cfg.policy, pool, workers)
    } else if workers <= 1 {
        drain_serial(transport, plan, codec, agg, cfg.mode, cfg.policy, pool)
    } else {
        drain_decode_workers(transport, plan, codec, agg, cfg.mode, cfg.policy, pool, workers)
    }?;
    report.pool = pool.stats().delta_since(pool_before);
    Ok(report)
}

/// Per-round admission gate + completion policy, shared by every drain
/// path (serial, decode-workers, shard-routed, and the round-resident
/// [`DrainPipeline`](super::DrainPipeline)) so all of them reject the same
/// malformed inputs, count the same faults, and finish under the same
/// quorum/deadline rules.
///
/// The gate owns the per-round slot bitmap: the first well-formed record
/// per `(round, slot)` wins; everything else is counted and dropped.
/// Transport data must never panic the server, so all of this is
/// recoverable accounting; `MaskServer::absorb` re-checks the slot
/// invariants with a panic to protect `Aggregator` drivers other than
/// these loops (the two layers are intentionally redundant).
pub(crate) struct RoundGate {
    round: usize,
    expected: usize,
    quorum: usize,
    deadline: Option<Instant>,
    on_decode_error: OnDecodeError,
    seen: Vec<bool>,
    accepted: usize,
    /// In-band failure reasons, embedded in shortfall errors so a round
    /// that dies of client failures says *which* clients and *why*.
    failures: Vec<String>,
    counters: FaultCounters,
}

impl RoundGate {
    pub(crate) fn new(plan: &RoundPlan, policy: &DrainPolicy) -> Self {
        let expected = plan.expected();
        Self {
            round: plan.round,
            expected,
            quorum: policy.quorum_count(expected),
            deadline: policy.deadline(),
            on_decode_error: policy.on_decode_error,
            seen: vec![false; expected],
            accepted: 0,
            failures: Vec::new(),
            counters: FaultCounters::default(),
        }
    }

    /// Records admitted so far (= jobs handed to the decode stage).
    pub(crate) fn accepted(&self) -> usize {
        self.accepted
    }

    /// Pull the next admissible record. `Ok(Some((slot, enc)))` admits a
    /// record; `Ok(None)` means intake is over (every planned record
    /// admitted, or the uplink closed / the deadline expired with the
    /// quorum met); `Err` means intake ended below the quorum.
    pub(crate) fn next_record(
        &mut self,
        transport: &mut dyn Transport,
        report: &mut DrainReport,
    ) -> Result<Option<(usize, Encoded)>> {
        loop {
            if self.accepted == self.expected {
                return Ok(None);
            }
            let msg = match self.deadline {
                None => match transport.recv() {
                    Some(msg) => msg,
                    None => return self.on_closed(),
                },
                Some(deadline) => match transport.recv_deadline(deadline) {
                    RecvOutcome::Msg(msg) => msg,
                    RecvOutcome::Closed => return self.on_closed(),
                    RecvOutcome::TimedOut => return self.on_deadline(transport),
                },
            };
            if let Some(admitted) = self.admit(msg, report) {
                return Ok(Some(admitted));
            }
        }
    }

    /// Apply the admission rules to one message. `None` = counted and
    /// dropped.
    fn admit(&mut self, msg: WireMessage, report: &mut DrainReport) -> Option<(usize, Encoded)> {
        self.counters.received += 1;
        if msg.round != self.round {
            self.counters.stale += 1;
            return None;
        }
        let enc = match msg.payload {
            Payload::Update(enc) => enc,
            Payload::Failed(err) => {
                self.counters.failed += 1;
                self.failures
                    .push(format!("client {} failed: {err}", msg.client_id));
                return None;
            }
        };
        if msg.slot >= self.expected {
            self.counters.bad_slot += 1;
            return None;
        }
        if self.seen[msg.slot] {
            self.counters.duplicates += 1;
            return None;
        }
        self.seen[msg.slot] = true;
        self.accepted += 1;
        self.counters.accepted += 1;
        report.loss_by_slot[msg.slot] = msg.loss as f64;
        report.enc_by_slot[msg.slot] = msg.enc_secs;
        Some((msg.slot, enc))
    }

    fn on_closed(&mut self) -> Result<Option<(usize, Encoded)>> {
        if self.accepted >= self.quorum {
            Ok(None)
        } else {
            Err(self.shortfall("uplink closed", self.accepted))
        }
    }

    fn on_deadline(&mut self, transport: &mut dyn Transport) -> Result<Option<(usize, Encoded)>> {
        // Late sweep: count whatever already arrived past the deadline
        // without waiting on anything further. Late current-round records
        // are *not* absorbed — completion must not depend on how late a
        // straggler is, only on the deadline.
        while let Some(msg) = transport.try_recv() {
            self.counters.received += 1;
            if msg.round == self.round {
                self.counters.late += 1;
            } else {
                self.counters.stale += 1;
            }
        }
        if self.accepted >= self.quorum {
            Ok(None)
        } else {
            Err(self.shortfall("round deadline expired", self.accepted))
        }
    }

    /// Handle a decode failure per policy: `Err` aborts the round, `Ok`
    /// counts the record as corrupt and lets the drain continue.
    pub(crate) fn decode_failed(&mut self, slot: usize, err: anyhow::Error) -> Result<()> {
        match self.on_decode_error {
            OnDecodeError::Abort => Err(anyhow!("decode failed for slot {slot}: {err}")),
            OnDecodeError::Skip => {
                self.counters.corrupt += 1;
                Ok(())
            }
        }
    }

    /// Final verdict once every admitted record has settled: `absorbed`
    /// is how many reached the aggregator (decode skips may put it below
    /// `accepted`). Writes the fault counters into the report and returns
    /// whether the round is partial (finish via
    /// [`Aggregator::finish_round_partial`]).
    pub(crate) fn settle(&self, absorbed: usize, report: &mut DrainReport) -> Result<bool> {
        report.faults = self.counters;
        report.faults.missing = (self.expected - absorbed) as u64;
        report.quorum_met = absorbed >= self.quorum;
        report.degraded = absorbed < self.expected;
        if !report.quorum_met {
            return Err(self.shortfall("quorum unmet", absorbed));
        }
        Ok(report.degraded)
    }

    fn shortfall(&self, reason: &str, count: usize) -> anyhow::Error {
        let mut msg = format!(
            "{reason} after {count}/{} updates (quorum {})",
            self.expected, self.quorum
        );
        if !self.failures.is_empty() {
            msg.push_str("; ");
            msg.push_str(&self.failures.join("; "));
        }
        anyhow!(msg)
    }
}

/// The single-threaded reference drain (`DrainConfig::serial`).
fn drain_serial(
    transport: &mut dyn Transport,
    plan: &RoundPlan,
    codec: &dyn UpdateCodec,
    agg: &mut dyn Aggregator,
    mode: PipelineMode,
    policy: DrainPolicy,
    pool: &ScratchPool,
) -> Result<DrainReport> {
    let expected = plan.expected();
    let mut report = DrainReport::new(expected, 1);
    let mut gate = RoundGate::new(plan, &policy);
    let mut absorbed = 0usize;

    // Decode + absorb one admitted record, per decode-error policy.
    fn decode_absorb(
        codec: &dyn UpdateCodec,
        plan: &RoundPlan,
        slot: usize,
        enc: &Encoded,
        agg: &mut dyn Aggregator,
        pool: &ScratchPool,
        gate: &mut RoundGate,
        report: &mut DrainReport,
        absorbed: &mut usize,
    ) -> Result<()> {
        let t = Stopwatch::new();
        match codec.decode_pooled(&enc.bytes, &plan.decode_ctx(slot), pool) {
            Ok(update) => {
                report.dec_secs += t.elapsed_secs();
                agg.absorb(slot, update);
                while let Some(buf) = agg.reclaim_buffer() {
                    pool.put(buf);
                }
                *absorbed += 1;
                Ok(())
            }
            Err(e) => gate.decode_failed(slot, e),
        }
    }

    match mode {
        PipelineMode::Streaming => {
            agg.begin_round(expected);
            while let Some((slot, enc)) = gate.next_record(transport, &mut report)? {
                decode_absorb(
                    codec,
                    plan,
                    slot,
                    &enc,
                    agg,
                    pool,
                    &mut gate,
                    &mut report,
                    &mut absorbed,
                )?;
            }
        }
        PipelineMode::Batch => {
            // Barrier first, then one begin/absorb×K/finish sweep in slot
            // order. Slots that never arrived stay `None` and are skipped.
            let mut buffered: Vec<Option<Encoded>> = vec![None; expected];
            while let Some((slot, enc)) = gate.next_record(transport, &mut report)? {
                buffered[slot] = Some(enc);
            }
            agg.begin_round(expected);
            for (slot, enc) in buffered.iter().enumerate() {
                if let Some(enc) = enc {
                    decode_absorb(
                        codec,
                        plan,
                        slot,
                        enc,
                        agg,
                        pool,
                        &mut gate,
                        &mut report,
                        &mut absorbed,
                    )?;
                }
            }
        }
    }
    bail_on_lane_fault(agg)?;
    let partial = gate.settle(absorbed, &mut report)?;
    if partial {
        agg.finish_round_partial();
    } else {
        agg.finish_round();
    }
    bail_on_lane_fault(agg)?;
    report.dec_by_worker[0] = report.dec_secs;
    Ok(report)
}

/// MPMC job queue feeding the decode workers: the draining thread pushes
/// `(slot, Encoded)` records, workers pop them under a condvar. `close`
/// stops intake but lets workers drain what remains; `abort` additionally
/// drops pending jobs (error shutdown). Shared with the round-resident
/// [`DrainPipeline`](super::DrainPipeline), which creates one per round.
pub(crate) struct DecodeQueue {
    state: Mutex<QueueState>,
    ready: Condvar,
}

struct QueueState {
    jobs: VecDeque<(usize, Encoded)>,
    closed: bool,
}

impl DecodeQueue {
    pub(crate) fn new() -> Self {
        Self {
            state: Mutex::new(QueueState {
                jobs: VecDeque::new(),
                closed: false,
            }),
            ready: Condvar::new(),
        }
    }

    pub(crate) fn push(&self, slot: usize, enc: Encoded) {
        self.state.lock().unwrap().jobs.push_back((slot, enc));
        self.ready.notify_one();
    }

    pub(crate) fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.ready.notify_all();
    }

    pub(crate) fn abort(&self) {
        let mut q = self.state.lock().unwrap();
        q.closed = true;
        q.jobs.clear();
        drop(q);
        self.ready.notify_all();
    }

    /// Next job, blocking until one is available; `None` once the queue is
    /// closed and drained.
    pub(crate) fn next(&self) -> Option<(usize, Encoded)> {
        let mut q = self.state.lock().unwrap();
        loop {
            if let Some(job) = q.jobs.pop_front() {
                return Some(job);
            }
            if q.closed {
                return None;
            }
            q = self.ready.wait(q).unwrap();
        }
    }
}

/// Decode one record for the dimension-sharded drain and hand its shard
/// splits to the absorb lanes. Mask-family codecs that support
/// range-restricted reconstruction ([`UpdateCodec::range_decoder`]) are
/// parsed/validated once here; the per-shard Eq. 5 sweeps then run **on
/// the lane threads** (each lane sweeps its own `d`-range into a buffer
/// leased from its own pool) — the full `d`-length reconstruction is
/// never materialized and no single thread sweeps the whole record.
/// Codecs without range support fall back to a full pooled decode split
/// at shard boundaries. Both paths are bitwise identical (the
/// [`MaskRangeDecoder`](crate::compress::MaskRangeDecoder) contract).
///
/// Returns the decode compute seconds spent on the **calling** thread
/// (parse/validate/filter-rebuild for the range path, the full decode for
/// the fallback) — routing hand-offs and lane backpressure are
/// deliberately outside the clock, and range-split sweep time is
/// accounted by the lanes (`absorb_secs_by_shard`).
pub(crate) fn decode_and_route(
    codec: &dyn UpdateCodec,
    plan: &RoundPlan,
    slot: usize,
    enc: &Encoded,
    pool: &ScratchPool,
    router: &ShardRouter,
) -> Result<f64> {
    let ctx = plan.decode_ctx(slot);
    let t = Stopwatch::new();
    if let Some(decoder) = codec.range_decoder(&enc.bytes, &ctx)? {
        let dec_secs = t.elapsed_secs();
        let decoder: Arc<dyn crate::compress::MaskRangeDecoder> = Arc::from(decoder);
        router.route_decoded_ranges(slot, &plan.mask_g, decoder);
        Ok(dec_secs)
    } else {
        let update = codec.decode_pooled(&enc.bytes, &ctx, pool)?;
        let dec_secs = t.elapsed_secs();
        router.route(slot, &update);
        pool.put(update.into_vec());
        Ok(dec_secs)
    }
}

/// Aborts the queue when dropped, so decode workers never outlive an
/// unwinding drain (e.g. an aggregator panic on the absorb stage).
struct QueueAbortGuard<'a>(&'a DecodeQueue);

impl Drop for QueueAbortGuard<'_> {
    fn drop(&mut self) {
        self.0.abort();
    }
}

/// One worker's finished decode, tagged for per-worker accounting.
struct DecodedRecord {
    slot: usize,
    worker: usize,
    dec_secs: f64,
    update: Result<Update>,
}

/// Fold one finished decode into the aggregator and recycle spent buffers.
/// Returns whether the record was absorbed (`false` = decode failure
/// skipped under [`OnDecodeError::Skip`]; an aborting failure is `Err`).
fn absorb_decoded(
    rec: DecodedRecord,
    report: &mut DrainReport,
    agg: &mut dyn Aggregator,
    pool: &ScratchPool,
    gate: &mut RoundGate,
) -> Result<bool> {
    let update = match rec.update {
        Ok(update) => update,
        Err(e) => {
            gate.decode_failed(rec.slot, e)?;
            return Ok(false);
        }
    };
    report.dec_secs += rec.dec_secs;
    report.dec_by_worker[rec.worker] += rec.dec_secs;
    agg.absorb(rec.slot, update);
    while let Some(buf) = agg.reclaim_buffer() {
        pool.put(buf);
    }
    Ok(true)
}

/// The sharded-decode drain: N decode workers + the absorb stage on the
/// draining thread. See the module docs for the stage layout and the
/// shutdown discipline — which [`route_from_workers`] twins for the
/// dimension-sharded drain; keep fixes to either shutdown path in sync.
fn drain_decode_workers(
    transport: &mut dyn Transport,
    plan: &RoundPlan,
    codec: &dyn UpdateCodec,
    agg: &mut dyn Aggregator,
    mode: PipelineMode,
    policy: DrainPolicy,
    pool: &ScratchPool,
    workers: usize,
) -> Result<DrainReport> {
    let expected = plan.expected();
    let mut report = DrainReport::new(expected, workers);
    let mut gate = RoundGate::new(plan, &policy);
    let mut absorbed = 0usize;
    let queue = DecodeQueue::new();

    if mode == PipelineMode::Streaming {
        agg.begin_round(expected);
    }

    let drained: Result<()> = std::thread::scope(|scope| {
        // Bounded results channel: at most `2 × workers` decoded d-length
        // updates sit between the workers and the absorb stage, so server
        // memory stays O(workers · d) however arrivals burst (a worker with
        // a finished decode blocks on `send` until the absorber catches
        // up). Created inside the scope so an unwinding absorb stage drops
        // the receiver before the scope joins the workers.
        let (tx, rx) = mpsc::sync_channel::<DecodedRecord>(workers * 2);
        let _abort_on_unwind = QueueAbortGuard(&queue);
        for worker in 0..workers {
            let tx = tx.clone();
            let queue = &queue;
            scope.spawn(move || {
                while let Some((slot, enc)) = queue.next() {
                    let t = Stopwatch::new();
                    let update = codec.decode_pooled(&enc.bytes, &plan.decode_ctx(slot), pool);
                    let rec = DecodedRecord {
                        slot,
                        worker,
                        dec_secs: t.elapsed_secs(),
                        update,
                    };
                    if tx.send(rec).is_err() {
                        return; // absorb stage bailed; discard and exit
                    }
                }
            });
        }
        // Only worker clones keep the channel open: once every worker has
        // exited, `rx` disconnects and the recv loops below terminate.
        drop(tx);

        let mut run = || -> Result<()> {
            // Settled = absorbed + skipped-as-corrupt: every job pushed to
            // the workers must come back before the round can finish.
            let mut settled = 0usize;
            match mode {
                PipelineMode::Streaming => {
                    while let Some((slot, enc)) = gate.next_record(transport, &mut report)? {
                        queue.push(slot, enc);
                        // Opportunistically absorb finished decodes between
                        // arrivals: keeps the in-flight set small and
                        // overlaps aggregation with transport waits.
                        while let Ok(rec) = rx.try_recv() {
                            if absorb_decoded(rec, &mut report, agg, pool, &mut gate)? {
                                absorbed += 1;
                            }
                            settled += 1;
                        }
                    }
                }
                PipelineMode::Batch => {
                    // Barrier first (the reference semantics), then fan the
                    // buffered records out to the workers in slot order —
                    // slots that never arrived are skipped.
                    let mut buffered: Vec<Option<Encoded>> = vec![None; expected];
                    while let Some((slot, enc)) = gate.next_record(transport, &mut report)? {
                        buffered[slot] = Some(enc);
                    }
                    agg.begin_round(expected);
                    for (slot, enc) in buffered.into_iter().enumerate() {
                        if let Some(enc) = enc {
                            queue.push(slot, enc);
                        }
                    }
                }
            }
            queue.close();
            while settled < gate.accepted() {
                let rec = rx
                    .recv()
                    .map_err(|_| anyhow!("decode workers exited early"))?;
                if absorb_decoded(rec, &mut report, agg, pool, &mut gate)? {
                    absorbed += 1;
                }
                settled += 1;
            }
            Ok(())
        };
        let out = run();
        if out.is_err() {
            // Clean abort: drop pending jobs, then drain the results
            // channel so workers blocked on the bounded `send` can exit
            // before the scope joins them. Their decodes are discarded.
            queue.abort();
            while rx.recv().is_ok() {}
        }
        out
    });
    drained?;
    bail_on_lane_fault(agg)?;
    let partial = gate.settle(absorbed, &mut report)?;
    if partial {
        agg.finish_round_partial();
    } else {
        agg.finish_round();
    }
    bail_on_lane_fault(agg)?;
    Ok(report)
}

/// The dimension-sharded drain (`DrainConfig::shards > 1`): every decoded
/// record is split at shard boundaries and handed to the aggregator's
/// per-shard absorb lanes through its [`ShardRouter`] — by the draining
/// thread when `workers == 1`, or by the decode workers themselves when
/// the decode stage is also sharded (the work-split the ROADMAP calls
/// per-`d`-range splitting: one huge record's absorb sweep runs on S
/// lanes instead of serializing on one thread). On any error the round
/// aborts cleanly: decode workers join and [`Aggregator::abort_round`]
/// tears the absorb lanes down before the error returns.
fn drain_shard_routed(
    transport: &mut dyn Transport,
    plan: &RoundPlan,
    codec: &dyn UpdateCodec,
    agg: &mut dyn Aggregator,
    mode: PipelineMode,
    policy: DrainPolicy,
    pool: &ScratchPool,
    workers: usize,
) -> Result<DrainReport> {
    let expected = plan.expected();
    let mut report = DrainReport::new(expected, workers);
    let mut gate = RoundGate::new(plan, &policy);

    // Batch mode: the full-round barrier comes first, before any lane is
    // spawned — a barrier failure therefore has nothing to tear down.
    let mut buffered: Vec<Option<Encoded>> = Vec::new();
    if mode == PipelineMode::Batch {
        buffered = vec![None; expected];
        while let Some((slot, enc)) = gate.next_record(transport, &mut report)? {
            buffered[slot] = Some(enc);
        }
    }

    agg.begin_round(expected);
    let router = match agg.shard_router() {
        Some(router) => router,
        None => {
            agg.abort_round();
            bail!(
                "DrainConfig::shards > 1 requires a dimension-sharded aggregator \
                 (coordinator::ShardedAggregator)"
            );
        }
    };

    let drained: Result<usize> = if workers <= 1 {
        // One decode at a time on this thread; the S absorb lanes run
        // concurrently behind the router (and for range-capable codecs the
        // lanes run the per-shard sweeps themselves, so even this
        // single-decode-worker shape parallelizes a record's sweep).
        let mut absorbed = 0usize;
        // Decode-and-route one record, per decode-error policy. A failed
        // decode routes nothing (both router paths validate before any
        // lane hand-off), so skipping it leaves the lanes consistent.
        fn decode_one(
            codec: &dyn UpdateCodec,
            plan: &RoundPlan,
            slot: usize,
            enc: &Encoded,
            pool: &ScratchPool,
            router: &ShardRouter,
            gate: &mut RoundGate,
            report: &mut DrainReport,
            absorbed: &mut usize,
        ) -> Result<()> {
            match decode_and_route(codec, plan, slot, enc, pool, router) {
                Ok(dec_secs) => {
                    report.dec_secs += dec_secs;
                    *absorbed += 1;
                    Ok(())
                }
                Err(e) => gate.decode_failed(slot, e),
            }
        }
        let mut run = || -> Result<()> {
            match mode {
                PipelineMode::Streaming => {
                    while let Some((slot, enc)) = gate.next_record(transport, &mut report)? {
                        decode_one(
                            codec,
                            plan,
                            slot,
                            &enc,
                            pool,
                            &router,
                            &mut gate,
                            &mut report,
                            &mut absorbed,
                        )?;
                    }
                }
                PipelineMode::Batch => {
                    for (slot, enc) in buffered.iter().enumerate() {
                        if let Some(enc) = enc {
                            decode_one(
                                codec,
                                plan,
                                slot,
                                enc,
                                pool,
                                &router,
                                &mut gate,
                                &mut report,
                                &mut absorbed,
                            )?;
                        }
                    }
                }
            }
            Ok(())
        };
        let out = run();
        report.dec_by_worker[0] = report.dec_secs;
        out.map(|()| absorbed)
    } else {
        route_from_workers(
            transport,
            plan,
            codec,
            &router,
            mode,
            pool,
            workers,
            &mut gate,
            &mut report,
            buffered,
        )
    };

    drop(router);
    let settled = drained
        .and_then(|absorbed| bail_on_lane_fault(agg).map(|()| absorbed))
        .and_then(|absorbed| gate.settle(absorbed, &mut report));
    match settled {
        Ok(partial) => {
            if partial {
                agg.finish_round_partial();
            } else {
                agg.finish_round();
            }
            bail_on_lane_fault(agg)?;
            Ok(report)
        }
        Err(e) => {
            agg.abort_round();
            Err(e)
        }
    }
}

/// One worker's accounting for a decoded-and-routed record: the payload
/// itself went straight to the absorb lanes, so only the outcome and the
/// timing travel back to the draining thread.
struct RoutedRecord {
    slot: usize,
    worker: usize,
    dec_secs: f64,
    outcome: Result<()>,
}

/// Fold one routed record's accounting into the report. Returns whether
/// the record was absorbed (`false` = decode failure skipped under
/// [`OnDecodeError::Skip`]; an aborting failure is `Err`).
fn settle_routed(rec: RoutedRecord, report: &mut DrainReport, gate: &mut RoundGate) -> Result<bool> {
    if let Err(e) = rec.outcome {
        gate.decode_failed(rec.slot, e)?;
        return Ok(false);
    }
    report.dec_secs += rec.dec_secs;
    report.dec_by_worker[rec.worker] += rec.dec_secs;
    Ok(true)
}

/// Decode stage of the dimension-sharded drain: N scoped workers decode
/// records and route each one's shard splits themselves. The worker-pool
/// scaffold and shutdown discipline (queue close/abort ordering, tx drop,
/// results drain before join) are a deliberate twin of
/// [`drain_decode_workers`] — only the per-record action differs (route +
/// recycle on the worker here vs absorb on the draining thread there);
/// keep any fix to either shutdown path in sync with the other. The
/// absorb lanes stay alive throughout (they belong to the aggregator), so
/// a worker blocked routing into a full lane queue always drains and
/// exits.
#[allow(clippy::too_many_arguments)]
fn route_from_workers(
    transport: &mut dyn Transport,
    plan: &RoundPlan,
    codec: &dyn UpdateCodec,
    router: &ShardRouter,
    mode: PipelineMode,
    pool: &ScratchPool,
    workers: usize,
    gate: &mut RoundGate,
    report: &mut DrainReport,
    buffered: Vec<Option<Encoded>>,
) -> Result<usize> {
    let queue = DecodeQueue::new();
    let mut absorbed = 0usize;
    let drained: Result<()> = std::thread::scope(|scope| {
        let (tx, rx) = mpsc::sync_channel::<RoutedRecord>(workers * 2);
        let _abort_on_unwind = QueueAbortGuard(&queue);
        for worker in 0..workers {
            let tx = tx.clone();
            let queue = &queue;
            let router = router.clone();
            scope.spawn(move || {
                while let Some((slot, enc)) = queue.next() {
                    // Range-capable codecs are parsed here and swept on
                    // the lanes; the rest decode fully, split, and recycle
                    // their buffer. Either way the clock covers only this
                    // thread's decode compute, not routing backpressure.
                    let (dec_secs, outcome) =
                        match decode_and_route(codec, plan, slot, &enc, pool, &router) {
                            Ok(secs) => (secs, Ok(())),
                            Err(e) => (0.0, Err(e)),
                        };
                    let rec = RoutedRecord {
                        slot,
                        worker,
                        dec_secs,
                        outcome,
                    };
                    if tx.send(rec).is_err() {
                        return; // draining thread bailed; exit
                    }
                }
            });
        }
        drop(tx);

        let mut run = || -> Result<()> {
            let mut settled = 0usize;
            match mode {
                PipelineMode::Streaming => {
                    while let Some((slot, enc)) = gate.next_record(transport, report)? {
                        queue.push(slot, enc);
                        while let Ok(rec) = rx.try_recv() {
                            if settle_routed(rec, report, gate)? {
                                absorbed += 1;
                            }
                            settled += 1;
                        }
                    }
                }
                PipelineMode::Batch => {
                    // Barrier already passed in the caller: fan out in
                    // slot order, skipping slots that never arrived.
                    for (slot, enc) in buffered.into_iter().enumerate() {
                        if let Some(enc) = enc {
                            queue.push(slot, enc);
                        }
                    }
                }
            }
            queue.close();
            while settled < gate.accepted() {
                let rec = rx
                    .recv()
                    .map_err(|_| anyhow!("decode workers exited early"))?;
                if settle_routed(rec, report, gate)? {
                    absorbed += 1;
                }
                settled += 1;
            }
            Ok(())
        };
        let out = run();
        if out.is_err() {
            queue.abort();
            while rx.recv().is_ok() {}
        }
        out
    });
    drained.map(|()| absorbed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress;
    use crate::coordinator::round::RoundEngine;
    use crate::coordinator::transport::{ChannelTransport, WireMessage};
    use crate::fl::server::MaskServer;
    use crate::model::sample_mask_seeded;

    #[derive(Default)]
    struct Spy {
        begun: Option<usize>,
        absorbed: Vec<usize>,
        finished: bool,
        finished_partial: bool,
    }

    impl Aggregator for Spy {
        fn begin_round(&mut self, expected: usize) {
            self.begun = Some(expected);
        }

        fn absorb(&mut self, slot: usize, _update: Update) {
            self.absorbed.push(slot);
        }

        fn finish_round(&mut self) {
            self.finished = true;
        }

        fn finish_round_partial(&mut self) {
            self.finished = true;
            self.finished_partial = true;
        }
    }

    fn plan_of(n: usize) -> RoundPlan {
        let theta = vec![0.5f32; 16];
        let s = vec![0.0f32; 16];
        RoundEngine::new(1, n, 1.0, 0.8, 0.25, 3).plan(0, &theta, &s)
    }

    fn msg(slot: usize, payload: Payload) -> WireMessage {
        WireMessage {
            round: 0,
            client_id: slot,
            slot,
            payload,
            enc_secs: 0.0,
            loss: 0.25,
        }
    }

    /// A valid FedPM record for `slot` of `plan` (decodable by any worker).
    fn fedpm_record(plan: &RoundPlan, slot: usize) -> Payload {
        let codec = compress::by_name("fedpm").unwrap();
        let mut mask_k = Vec::new();
        sample_mask_seeded(&plan.theta_g, plan.client_seed(slot), &mut mask_k);
        let enc = codec
            .encode(&plan.encode_ctx(slot, &plan.theta_g, &mask_k, &[]))
            .unwrap();
        Payload::Update(enc)
    }

    #[test]
    fn failed_client_surfaces_as_error() {
        let plan = plan_of(2);
        let codec = compress::by_name("fedpm").unwrap();
        let (mut transport, sender) = ChannelTransport::new();
        sender
            .send(msg(0, Payload::Failed("client oom".into())))
            .unwrap();
        drop(sender);
        let mut spy = Spy::default();
        let err = drain_round(
            &mut transport,
            &plan,
            codec.as_ref(),
            &mut spy,
            DrainConfig::serial(PipelineMode::Batch),
            &ScratchPool::new(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("client oom"), "{err}");
        assert!(!spy.finished);
    }

    #[test]
    fn duplicate_slot_counts_against_quorum_under_strict_policy() {
        let plan = plan_of(2);
        let codec = compress::by_name("fedpm").unwrap();
        let (mut transport, sender) = ChannelTransport::new();
        // Batch mode defers decoding, so garbage payloads are fine here.
        let junk = Payload::Update(Encoded { bytes: vec![0; 4] });
        sender.send(msg(1, junk.clone())).unwrap();
        sender.send(msg(1, junk)).unwrap();
        drop(sender);
        let mut spy = Spy::default();
        let err = drain_round(
            &mut transport,
            &plan,
            codec.as_ref(),
            &mut spy,
            DrainConfig::serial(PipelineMode::Batch),
            &ScratchPool::new(),
        )
        .unwrap_err();
        // The duplicate is dropped, not fatal; the round then dies of the
        // missing slot-0 record under the strict all-must-report quorum.
        assert!(err.to_string().contains("1/2"), "{err}");
    }

    #[test]
    fn first_record_wins_and_rejections_are_counted() {
        let plan = plan_of(3);
        let codec = compress::by_name("fedpm").unwrap();
        for mode in [PipelineMode::Streaming, PipelineMode::Batch] {
            let (mut transport, sender) = ChannelTransport::new();
            sender.send(msg(0, fedpm_record(&plan, 0))).unwrap();
            // Duplicate of slot 0: dropped, first record wins.
            sender.send(msg(0, fedpm_record(&plan, 0))).unwrap();
            // Stale replay from another round: dropped.
            let mut stale = msg(1, fedpm_record(&plan, 1));
            stale.round = 7;
            sender.send(stale).unwrap();
            // Out-of-range slot from a buggy client: dropped.
            sender.send(msg(99, fedpm_record(&plan, 1))).unwrap();
            sender.send(msg(2, fedpm_record(&plan, 2))).unwrap();
            drop(sender); // slot 1 never reports
            let mut spy = Spy::default();
            let report = drain_round(
                &mut transport,
                &plan,
                codec.as_ref(),
                &mut spy,
                DrainConfig::serial(mode).with_policy(DrainPolicy {
                    quorum: 0.5,
                    ..DrainPolicy::default()
                }),
                &ScratchPool::new(),
            )
            .unwrap();
            let mut slots = spy.absorbed.clone();
            slots.sort_unstable();
            assert_eq!(slots, vec![0, 2], "{mode:?}");
            assert!(spy.finished_partial, "{mode:?}");
            assert_eq!(report.faults.received, 5, "{mode:?}");
            assert_eq!(report.faults.accepted, 2, "{mode:?}");
            assert_eq!(report.faults.duplicates, 1, "{mode:?}");
            assert_eq!(report.faults.stale, 1, "{mode:?}");
            assert_eq!(report.faults.bad_slot, 1, "{mode:?}");
            assert_eq!(report.faults.missing, 1, "{mode:?}");
            assert!(report.quorum_met && report.degraded, "{mode:?}");
        }
    }

    #[test]
    fn quorum_lets_a_failed_client_degrade_instead_of_abort() {
        let plan = plan_of(2);
        let codec = compress::by_name("fedpm").unwrap();
        let (mut transport, sender) = ChannelTransport::new();
        sender.send(msg(0, fedpm_record(&plan, 0))).unwrap();
        sender
            .send(msg(1, Payload::Failed("client oom".into())))
            .unwrap();
        drop(sender);
        let mut spy = Spy::default();
        let report = drain_round(
            &mut transport,
            &plan,
            codec.as_ref(),
            &mut spy,
            DrainConfig::serial(PipelineMode::Streaming).with_policy(DrainPolicy {
                quorum: 0.5,
                ..DrainPolicy::default()
            }),
            &ScratchPool::new(),
        )
        .unwrap();
        assert_eq!(spy.absorbed, vec![0]);
        assert!(spy.finished_partial);
        assert_eq!(report.faults.failed, 1);
        assert_eq!(report.faults.missing, 1);
        assert!(report.degraded);
    }

    #[test]
    fn skip_policy_counts_undecodable_records_as_corrupt() {
        let plan = plan_of(2);
        let codec = compress::by_name("fedpm").unwrap();
        let skip = DrainPolicy {
            quorum: 0.5,
            on_decode_error: OnDecodeError::Skip,
            ..DrainPolicy::default()
        };
        // Across the serial and decode-worker paths, both modes.
        for workers in [1usize, 3] {
            for mode in [PipelineMode::Streaming, PipelineMode::Batch] {
                let (mut transport, sender) = ChannelTransport::new();
                sender.send(msg(0, fedpm_record(&plan, 0))).unwrap();
                sender
                    .send(msg(1, Payload::Update(Encoded { bytes: vec![0; 3] })))
                    .unwrap();
                drop(sender);
                let mut spy = Spy::default();
                let report = drain_round(
                    &mut transport,
                    &plan,
                    codec.as_ref(),
                    &mut spy,
                    DrainConfig::new(mode, workers).with_policy(skip),
                    &ScratchPool::new(),
                )
                .unwrap();
                assert_eq!(spy.absorbed, vec![0], "w{workers} {mode:?}");
                assert!(spy.finished_partial, "w{workers} {mode:?}");
                assert_eq!(report.faults.corrupt, 1, "w{workers} {mode:?}");
                assert_eq!(report.faults.missing, 1, "w{workers} {mode:?}");
                assert!(report.degraded, "w{workers} {mode:?}");
            }
        }
        // Under the default abort policy the same round errors.
        let (mut transport, sender) = ChannelTransport::new();
        sender.send(msg(0, fedpm_record(&plan, 0))).unwrap();
        sender
            .send(msg(1, Payload::Update(Encoded { bytes: vec![0; 3] })))
            .unwrap();
        drop(sender);
        let mut spy = Spy::default();
        let err = drain_round(
            &mut transport,
            &plan,
            codec.as_ref(),
            &mut spy,
            DrainConfig::serial(PipelineMode::Streaming),
            &ScratchPool::new(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("decode failed for slot 1"), "{err}");
    }

    #[test]
    fn deadline_expiry_finishes_with_quorum_or_errors_without() {
        let plan = plan_of(2);
        let codec = compress::by_name("fedpm").unwrap();
        // Quorum met at the deadline: the round finishes degraded even
        // though one sender handle is still alive (a hung client).
        let (mut transport, sender) = ChannelTransport::new();
        sender.send(msg(0, fedpm_record(&plan, 0))).unwrap();
        let mut spy = Spy::default();
        let report = drain_round(
            &mut transport,
            &plan,
            codec.as_ref(),
            &mut spy,
            DrainConfig::serial(PipelineMode::Streaming).with_policy(DrainPolicy {
                quorum: 0.5,
                deadline_ms: 40,
                ..DrainPolicy::default()
            }),
            &ScratchPool::new(),
        )
        .unwrap();
        assert_eq!(spy.absorbed, vec![0]);
        assert!(report.degraded && report.quorum_met);
        // Quorum unmet at the deadline: the round errors with progress.
        let (mut transport2, sender2) = ChannelTransport::new();
        let mut spy = Spy::default();
        let err = drain_round(
            &mut transport2,
            &plan,
            codec.as_ref(),
            &mut spy,
            DrainConfig::serial(PipelineMode::Streaming).with_policy(DrainPolicy {
                quorum: 1.0,
                deadline_ms: 10,
                ..DrainPolicy::default()
            }),
            &ScratchPool::new(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("deadline expired"), "{err}");
        assert!(err.to_string().contains("0/2"), "{err}");
        drop(sender);
        drop(sender2);
    }

    #[test]
    fn early_close_reports_progress() {
        let plan = plan_of(3);
        let codec = compress::by_name("fedpm").unwrap();
        let (mut transport, sender) = ChannelTransport::new();
        drop(sender); // no client ever reports
        let mut spy = Spy::default();
        let err = drain_round(
            &mut transport,
            &plan,
            codec.as_ref(),
            &mut spy,
            DrainConfig::serial(PipelineMode::Streaming),
            &ScratchPool::new(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("0/3"), "{err}");
        assert_eq!(spy.begun, Some(3), "streaming begins before the drain");
    }

    #[test]
    fn sharded_drain_absorbs_every_slot_exactly_once() {
        let n = 5;
        let plan = plan_of(n);
        let codec = compress::by_name("fedpm").unwrap();
        for mode in [PipelineMode::Streaming, PipelineMode::Batch] {
            let (mut transport, sender) = ChannelTransport::new();
            for slot in (0..n).rev() {
                sender.send(msg(slot, fedpm_record(&plan, slot))).unwrap();
            }
            drop(sender);
            let mut spy = Spy::default();
            let report = drain_round(
                &mut transport,
                &plan,
                codec.as_ref(),
                &mut spy,
                DrainConfig::new(mode, 3),
                &ScratchPool::new(),
            )
            .unwrap();
            assert_eq!(spy.begun, Some(n), "{mode:?}");
            assert!(spy.finished, "{mode:?}");
            let mut slots = spy.absorbed.clone();
            slots.sort_unstable();
            assert_eq!(slots, (0..n).collect::<Vec<_>>(), "{mode:?}");
            assert_eq!(report.dec_by_worker.len(), 3, "{mode:?}");
        }
    }

    #[test]
    fn sharded_early_close_aborts_cleanly() {
        let plan = plan_of(4);
        let codec = compress::by_name("fedpm").unwrap();
        let (mut transport, sender) = ChannelTransport::new();
        sender.send(msg(1, fedpm_record(&plan, 1))).unwrap();
        drop(sender); // the other three clients never report
        let mut agg = MaskServer::with_theta0(16, 1.0, 0.5);
        let err = drain_round(
            &mut transport,
            &plan,
            codec.as_ref(),
            &mut agg,
            DrainConfig::new(PipelineMode::Streaming, 2),
            &ScratchPool::new(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("1/4"), "{err}");
    }
}
