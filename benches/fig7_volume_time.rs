//! **Figure 7 (= Fig. 5 + Fig. 6)** — data volume to reach within 1% of
//! peak accuracy (normalized against fine-tuning) and encode/decode CPU
//! time per update, CIFAR-100-sim with N=10.
//!
//!     cargo bench --bench fig7_volume_time [-- --full]
//!
//! Shape claims: FedCode minimal volume but slow encode + lowest accuracy;
//! DeepReduce slowest enc/dec (Bloom); DeltaMask ≈ FedPM accuracy with far
//! less data and fast encode.

use deltamask::bench::{BenchScale, Table};
use deltamask::fl::run_experiment;
use deltamask::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let scale = BenchScale::from_args(&args);
    let methods = [
        "fine_tuning",
        "fedmask",
        "eden",
        "drive",
        "fedcode",
        "deepreduce",
        "fedpm",
        "deltamask",
    ];

    let mut results = Vec::new();
    for method in methods {
        let mut cfg = scale.config("cifar100", method);
        cfg.eval_every = 2; // fine-grained volume-to-accuracy curve
        let res = run_experiment(&cfg)?;
        eprintln!(
            "  {method}: peak={:.4} vol1%={:?} enc={:.3}ms dec={:.3}ms",
            res.peak_accuracy(),
            res.volume_to_within(0.01),
            res.mean_enc_ms(),
            res.mean_dec_ms()
        );
        results.push((method, res));
    }
    let ft_volume = results
        .iter()
        .find(|(m, _)| *m == "fine_tuning")
        .and_then(|(_, r)| r.volume_to_within(0.01))
        .unwrap_or(1.0);

    let mut table = Table::new(
        "Figure 7: relative data volume (vs FT) + encode/decode time",
        &["method", "peak acc", "rel volume", "enc ms", "dec ms"],
    );
    for (method, res) in &results {
        let vol = res
            .volume_to_within(0.01)
            .map(|v| format!("{:.4}", v / ft_volume))
            .unwrap_or_else(|| "n/a".into());
        table.row(vec![
            method.to_string(),
            format!("{:.4}", res.peak_accuracy()),
            vol,
            format!("{:.3}", res.mean_enc_ms()),
            format!("{:.3}", res.mean_dec_ms()),
        ]);
    }
    table.print();
    table.save("fig7_volume_time");
    Ok(())
}
