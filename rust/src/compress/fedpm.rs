//! **FedPM** (Isik et al. 2023b) — stochastic binary masks entropy-coded
//! with arithmetic coding (§2: "to reduce the bitrate below 1 bpp, FedPM
//! employs arithmetic coding to encode masks based on the sparsity level").
//!
//! The whole sampled mask m^{k,t} is transmitted each round; the adaptive
//! order-0 coder lands near H(p̄) bits/parameter where p̄ is the mask's
//! activation frequency — ≈0.8–0.95 bpp in practice, exactly the paper's
//! reported FedPM regime.

use super::{wire, DecodeCtx, EncodeCtx, Encoded, Family, Update, UpdateCodec};
use crate::codec::arith;
use anyhow::{ensure, Result};

pub struct FedPmCodec;

impl UpdateCodec for FedPmCodec {
    fn name(&self) -> &'static str {
        "fedpm"
    }

    fn family(&self) -> Family {
        Family::Mask
    }

    fn encode(&self, ctx: &EncodeCtx) -> Result<Encoded> {
        let bits: Vec<bool> = ctx.mask_k.iter().map(|&m| m > 0.5).collect();
        let coded = arith::encode_bits(&bits);
        let mut bytes = Vec::with_capacity(coded.len() + 8);
        wire::put_u32(&mut bytes, ctx.d as u32);
        wire::put_u32(&mut bytes, coded.len() as u32);
        bytes.extend_from_slice(&coded);
        Ok(Encoded { bytes })
    }

    fn decode(&self, bytes: &[u8], ctx: &DecodeCtx) -> Result<Update> {
        let mut r = wire::Reader::new(bytes);
        let d = r.u32()? as usize;
        ensure!(d == ctx.d, "dimension mismatch");
        let n = r.u32()? as usize;
        let coded = r.bytes(n)?;
        let bits = arith::decode_bits(coded, d);
        Ok(Update::Mask(
            bits.into_iter().map(|b| if b { 1.0 } else { 0.0 }).collect(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::sample_mask_seeded;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn lossless_roundtrip_and_sub_one_bpp_when_biased() {
        let d = 100_000;
        let mut rng = Xoshiro256pp::new(1);
        // Trained masks drift off 0.5 — e.g. mean activation 0.3.
        let theta: Vec<f32> = (0..d)
            .map(|_| if rng.next_f32() < 0.5 { 0.1 } else { 0.5 })
            .collect();
        let mut mask = Vec::new();
        sample_mask_seeded(&theta, 2, &mut mask);
        let ctx = EncodeCtx {
            d,
            theta_k: &theta,
            theta_g: &theta,
            mask_k: &mask,
            mask_g: &mask,
            s_k: &[],
            s_g: &[],
            kappa: 1.0,
            seed: 0,
        };
        let codec = FedPmCodec;
        let enc = codec.encode(&ctx).unwrap();
        let p = mask.iter().sum::<f32>() / d as f32;
        let h = arith::binary_entropy(p as f64);
        assert!(
            enc.bpp(d) < h + 0.05,
            "bpp={} entropy={h}",
            enc.bpp(d)
        );
        let dctx = DecodeCtx {
            d,
            mask_g: &mask,
            s_g: &[],
            seed: 0,
        };
        let Update::Mask(m) = codec.decode(&enc.bytes, &dctx).unwrap() else {
            panic!()
        };
        assert_eq!(m, mask, "FedPM must be lossless");
    }
}
