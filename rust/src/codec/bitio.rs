//! LSB-first bit readers/writers as used by DEFLATE (RFC 1951 §3.1.1):
//! data elements are packed starting from the least-significant bit of each
//! byte; Huffman codes are packed most-significant-code-bit first, which the
//! caller handles by reversing code bits.

#[derive(Debug, Default)]
pub struct BitWriter {
    out: Vec<u8>,
    bitbuf: u64,
    bitcount: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Write the low `n` bits of `value`, LSB-first.
    #[inline]
    pub fn write_bits(&mut self, value: u32, n: u32) {
        debug_assert!(n <= 32);
        debug_assert!(n == 32 || value < (1u32 << n));
        self.bitbuf |= (value as u64) << self.bitcount;
        self.bitcount += n;
        while self.bitcount >= 8 {
            self.out.push(self.bitbuf as u8);
            self.bitbuf >>= 8;
            self.bitcount -= 8;
        }
    }

    /// Pad to a byte boundary with zero bits.
    pub fn align_byte(&mut self) {
        if self.bitcount > 0 {
            self.out.push(self.bitbuf as u8);
            self.bitbuf = 0;
            self.bitcount = 0;
        }
    }

    pub fn write_bytes(&mut self, bytes: &[u8]) {
        debug_assert_eq!(self.bitcount, 0, "write_bytes requires byte alignment");
        self.out.extend_from_slice(bytes);
    }

    pub fn finish(mut self) -> Vec<u8> {
        self.align_byte();
        self.out
    }

    pub fn bit_len(&self) -> usize {
        self.out.len() * 8 + self.bitcount as usize
    }
}

#[derive(Debug)]
pub struct BitReader<'a> {
    data: &'a [u8],
    pos: usize,
    bitbuf: u64,
    bitcount: u32,
}

impl<'a> BitReader<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        Self {
            data,
            pos: 0,
            bitbuf: 0,
            bitcount: 0,
        }
    }

    #[inline]
    fn refill(&mut self) {
        while self.bitcount <= 56 && self.pos < self.data.len() {
            self.bitbuf |= (self.data[self.pos] as u64) << self.bitcount;
            self.pos += 1;
            self.bitcount += 8;
        }
    }

    /// Read `n` bits LSB-first. Reading past the end returns zero bits
    /// (callers detect truncation at a higher level).
    #[inline]
    pub fn read_bits(&mut self, n: u32) -> u32 {
        debug_assert!(n <= 32);
        if n == 0 {
            return 0;
        }
        self.refill();
        let v = (self.bitbuf & ((1u64 << n) - 1)) as u32;
        self.bitbuf >>= n;
        self.bitcount = self.bitcount.saturating_sub(n);
        v
    }

    /// Peek up to 16 bits without consuming.
    #[inline]
    pub fn peek_bits(&mut self, n: u32) -> u32 {
        self.refill();
        (self.bitbuf & ((1u64 << n) - 1)) as u32
    }

    #[inline]
    pub fn consume(&mut self, n: u32) {
        self.bitbuf >>= n;
        self.bitcount = self.bitcount.saturating_sub(n);
    }

    pub fn align_byte(&mut self) {
        let drop = self.bitcount % 8;
        self.consume(drop);
    }

    /// Copy `n` bytes after byte alignment.
    pub fn read_bytes(&mut self, n: usize) -> Option<Vec<u8>> {
        debug_assert_eq!(self.bitcount % 8, 0);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            self.refill();
            if self.bitcount < 8 {
                return None;
            }
            out.push(self.bitbuf as u8);
            self.consume(8);
        }
        Some(out)
    }

    /// True if all input has been consumed (ignoring sub-byte padding).
    pub fn exhausted(&mut self) -> bool {
        self.pos >= self.data.len() && self.bitcount < 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_mixed_widths() {
        let mut w = BitWriter::new();
        let pattern: Vec<(u32, u32)> = vec![
            (0b1, 1),
            (0b101, 3),
            (0xff, 8),
            (0x1234, 13),
            (0, 2),
            (0xabcd, 16),
            (1, 1),
        ];
        for &(v, n) in &pattern {
            w.write_bits(v & ((1 << n) - 1), n);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &(v, n) in &pattern {
            assert_eq!(r.read_bits(n), v & ((1 << n) - 1), "width {n}");
        }
    }

    #[test]
    fn byte_alignment_and_raw_bytes() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        w.align_byte();
        w.write_bytes(&[0xde, 0xad]);
        let bytes = w.finish();
        assert_eq!(bytes, vec![0b101, 0xde, 0xad]);
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(3), 0b101);
        r.align_byte();
        assert_eq!(r.read_bytes(2).unwrap(), vec![0xde, 0xad]);
        assert!(r.exhausted());
    }

    #[test]
    fn peek_consume_equivalence() {
        let mut w = BitWriter::new();
        for i in 0..64u32 {
            w.write_bits(i % 16, 4);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for i in 0..64u32 {
            let p = r.peek_bits(4);
            r.consume(4);
            assert_eq!(p, i % 16);
        }
    }

    #[test]
    fn reading_past_end_returns_zeros() {
        let mut r = BitReader::new(&[0xff]);
        assert_eq!(r.read_bits(8), 0xff);
        assert_eq!(r.read_bits(8), 0);
        assert!(r.exhausted());
    }
}
