//! Model-side math owned by the L3 coordinator: mask probabilities,
//! shared-seed Bernoulli sampling, KL ranking for top-κ selection,
//! Kaiming/weight initialization, and the state containers that flow
//! through the FL loop.

pub mod backend;

pub use backend::{Backend, ModelParams};

use crate::util::rng::Xoshiro256pp;

/// Static architecture configuration (mirrors python `ModelConfig`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArchConfig {
    pub f: usize,
    pub c: usize,
    pub b: usize,
    pub l: usize,
}

impl ArchConfig {
    pub fn new(f: usize, c: usize, b: usize, l: usize) -> Self {
        Self { f, c, b, l }
    }

    /// Mask dimensionality d = L·F².
    pub fn d(&self) -> usize {
        self.l * self.f * self.f
    }
}

#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// θ = σ(s), elementwise.
pub fn theta_from_scores(s: &[f32], out: &mut Vec<f32>) {
    out.clear();
    out.extend(s.iter().map(|&v| sigmoid(v)));
}

/// Deterministic Bernoulli sample m ~ Bern(θ) from a shared seed — the
/// §3.2 mechanism that lets every client (and the server) reconstruct the
/// identical global binary mask m^{g,t-1} without transmitting it.
pub fn sample_mask_seeded(theta: &[f32], seed: u64, out: &mut Vec<f32>) {
    let mut rng = Xoshiro256pp::new(seed);
    out.clear();
    out.extend(theta.iter().map(|&p| if rng.next_f32() < p { 1.0f32 } else { 0.0 }));
}

/// Bernoulli sample from explicit uniforms (the training-path form whose
/// uniforms also feed the XLA graph).
pub fn sample_mask_with_u(theta: &[f32], u: &[f32], out: &mut Vec<f32>) {
    debug_assert_eq!(theta.len(), u.len());
    out.clear();
    out.extend(
        theta
            .iter()
            .zip(u)
            .map(|(&p, &uu)| if uu < p { 1.0f32 } else { 0.0 }),
    );
}

/// Bernoulli(p ‖ q) KL divergence, the Eq. 4 ranking score. Clamped away
/// from {0,1} for numerical stability.
#[inline]
pub fn kl_bernoulli(p: f32, q: f32) -> f32 {
    let eps = 1e-6f32;
    let p = p.clamp(eps, 1.0 - eps);
    let q = q.clamp(eps, 1.0 - eps);
    p * (p / q).ln() + (1.0 - p) * ((1.0 - p) / (1.0 - q)).ln()
}

/// Per-round top-κ schedule: the paper uses "a cosine scheduler for the
/// top_κ mechanism starting from κ=0.8" (§4) — κ decays from κ₀ to
/// κ₀·floor_frac over the training horizon.
pub fn kappa_schedule(kappa0: f64, round: usize, total_rounds: usize, floor_frac: f64) -> f64 {
    if total_rounds <= 1 {
        return kappa0;
    }
    let t = (round as f64 / (total_rounds - 1) as f64).clamp(0.0, 1.0);
    let cos = 0.5 * (1.0 + (std::f64::consts::PI * t).cos());
    kappa0 * (floor_frac + (1.0 - floor_frac) * cos)
}

/// Mutable per-client mask-training state (scores + Adam moments).
#[derive(Clone, Debug)]
pub struct MaskState {
    pub s: Vec<f32>,
    pub mt: Vec<f32>,
    pub vt: Vec<f32>,
    pub step: u64,
}

impl MaskState {
    /// FedPM-style init: θ = 0.5 everywhere (s = 0).
    pub fn new(d: usize) -> Self {
        Self {
            s: vec![0.0; d],
            mt: vec![0.0; d],
            vt: vec![0.0; d],
            step: 0,
        }
    }

    /// Re-initialize scores from a received probability mask: s = logit(θ).
    /// Moments are preserved across rounds on each client (paper keeps
    /// optimizer state local).
    pub fn set_theta(&mut self, theta: &[f32]) {
        debug_assert_eq!(theta.len(), self.s.len());
        for (s, &p) in self.s.iter_mut().zip(theta) {
            let p = p.clamp(1e-6, 1.0 - 1e-6);
            *s = (p / (1.0 - p)).ln();
        }
    }
}

/// Frozen "pre-trained" weights + trainable head, generated deterministically
/// from a seed (the substitution for downloading CLIP/DINOv2 checkpoints —
/// DESIGN.md §2).
pub fn init_params(cfg: ArchConfig, seed: u64) -> backend::ModelParams {
    let mut rng = Xoshiro256pp::new(seed);
    // A *pre-trained* backbone behaves near-identity on its own feature
    // space (residual blocks refine, they don't scramble): we scale Kaiming
    // down so the frozen blocks are mild refiners. Masking then modulates
    // which refinement directions survive — the paper's premise that good
    // subnetworks of a pre-trained model exist. Pure Kaiming (scale 1.0)
    // would emulate the *random-init* supermask regime of FedPM instead.
    let kaiming = 0.4 * (2.0 / cfg.f as f32).sqrt();
    let mut w_blocks = vec![0.0f32; cfg.l * cfg.f * cfg.f];
    rng.fill_gaussian_f32(&mut w_blocks, 0.0, kaiming);
    let mut head_w = vec![0.0f32; cfg.c * cfg.f];
    rng.fill_gaussian_f32(&mut head_w, 0.0, 0.05);
    let head_b = vec![0.0f32; cfg.c];
    backend::ModelParams {
        cfg,
        w_blocks,
        head_w,
        head_b,
        head_version: 0,
    }
}

/// Accuracy from logits (B·C row-major) against integer labels, counting
/// only the first `n_valid` rows (tail padding from fixed-B graphs).
pub fn accuracy(logits: &[f32], labels: &[u32], c: usize, n_valid: usize) -> (usize, usize) {
    let mut correct = 0;
    for (row, &label) in labels.iter().enumerate().take(n_valid) {
        let start = row * c;
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (j, &v) in logits[start..start + c].iter().enumerate() {
            if v > best_v {
                best_v = v;
                best = j;
            }
        }
        if best == label as usize {
            correct += 1;
        }
    }
    (correct, n_valid)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_basics() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(10.0) > 0.9999);
        assert!(sigmoid(-10.0) < 0.0001);
    }

    #[test]
    fn seeded_sampling_is_shared() {
        // Identical (θ, seed) ⇒ identical mask — the §3.2 invariant.
        let theta: Vec<f32> = (0..1000).map(|i| i as f32 / 1000.0).collect();
        let mut a = Vec::new();
        let mut b = Vec::new();
        sample_mask_seeded(&theta, 42, &mut a);
        sample_mask_seeded(&theta, 42, &mut b);
        assert_eq!(a, b);
        sample_mask_seeded(&theta, 43, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn sampling_matches_probabilities() {
        let theta = vec![0.2f32; 50_000];
        let mut m = Vec::new();
        sample_mask_seeded(&theta, 7, &mut m);
        let frac = m.iter().sum::<f32>() / m.len() as f32;
        assert!((frac - 0.2).abs() < 0.01, "frac={frac}");
    }

    #[test]
    fn kl_properties() {
        assert!(kl_bernoulli(0.5, 0.5).abs() < 1e-6);
        assert!(kl_bernoulli(0.9, 0.1) > 1.0);
        assert!(kl_bernoulli(0.9, 0.5) > 0.0);
        // Larger probability gap ⇒ larger divergence.
        assert!(kl_bernoulli(0.9, 0.1) > kl_bernoulli(0.6, 0.4));
        // No NaN at the extremes.
        assert!(kl_bernoulli(0.0, 1.0).is_finite());
    }

    #[test]
    fn kappa_schedule_decays() {
        let k0 = kappa_schedule(0.8, 0, 100, 0.25);
        let k50 = kappa_schedule(0.8, 50, 100, 0.25);
        let k99 = kappa_schedule(0.8, 99, 100, 0.25);
        assert!((k0 - 0.8).abs() < 1e-9);
        assert!(k50 < k0 && k99 < k50);
        assert!(k99 >= 0.8 * 0.25 - 1e-9);
        assert_eq!(kappa_schedule(0.8, 0, 1, 0.25), 0.8);
    }

    #[test]
    fn set_theta_roundtrip() {
        let mut ms = MaskState::new(100);
        let theta: Vec<f32> = (0..100).map(|i| 0.01 + 0.98 * i as f32 / 99.0).collect();
        ms.set_theta(&theta);
        let mut back = Vec::new();
        theta_from_scores(&ms.s, &mut back);
        for (a, b) in theta.iter().zip(&back) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn accuracy_counts_valid_rows_only() {
        // 2 classes, 3 rows; padding row ignored.
        let logits = vec![1.0, 0.0, 0.0, 1.0, 1.0, 0.0];
        let labels = vec![0u32, 1, 1];
        let (c, n) = accuracy(&logits, &labels, 2, 2);
        assert_eq!((c, n), (2, 2));
        let (c, n) = accuracy(&logits, &labels, 2, 3);
        assert_eq!((c, n), (2, 3));
    }

    #[test]
    fn init_params_deterministic() {
        let cfg = ArchConfig::new(32, 10, 8, 5);
        let a = init_params(cfg, 9);
        let b = init_params(cfg, 9);
        assert_eq!(a.w_blocks, b.w_blocks);
        let c = init_params(cfg, 10);
        assert_ne!(a.w_blocks, c.w_blocks);
        // Scaled-Kaiming sanity (0.4 × √(2/F), the pre-trained-mildness knob).
        let std = crate::util::stats::std(&a.w_blocks.iter().map(|&x| x as f64).collect::<Vec<_>>());
        assert!((std - 0.4 * (2.0 / 32.0f64).sqrt()).abs() < 0.01, "std={std}");
    }
}
