//! Wall-clock timing helpers for the metrics pipeline and bench harness.

use std::time::Instant;

/// Simple stopwatch accumulating named spans.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn restart(&mut self) -> f64 {
        let e = self.elapsed_secs();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let sw = Stopwatch::new();
        let a = sw.elapsed_secs();
        let b = sw.elapsed_secs();
        assert!(b >= a);
        let (v, secs) = time_it(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
