//! Self-scheduling client execution pool.
//!
//! The seed pre-chunked participants round-robin across scoped threads, so
//! one straggler idled its whole chunk's thread-mates; and it moved
//! sessions out of the runner by swapping in zero-dimension placeholder
//! sessions — a latent footgun if a worker died mid-round. Here workers
//! claim the next job from a shared atomic cursor (work stealing in its
//! simplest form: the queue is the steal target), and sessions travel
//! through `Option` slots that are either intact or visibly empty — never a
//! fake session.
//!
//! The pool is generic over the session type so it stays independent of
//! `fl`; the runner instantiates it with `ClientSession`.

use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// A fixed-size worker pool executing one job per (client id, session) item.
#[derive(Clone, Copy, Debug)]
pub struct ClientPool {
    pub threads: usize,
}

struct Slot<S, T> {
    id: usize,
    sess: Option<S>,
    out: Option<Result<T>>,
}

impl ClientPool {
    pub fn new(threads: usize) -> Self {
        Self {
            threads: threads.max(1),
        }
    }

    /// One thread per hardware core, capped at the item count.
    pub fn sized_for(items: usize) -> Self {
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        Self::new(cores.min(items.max(1)))
    }

    /// Run `job` over every `(client_id, session)` item on the pool while
    /// `server_loop` runs concurrently on the calling thread.
    ///
    /// `job` is cloned once per worker (so per-worker resources such as
    /// transport senders clone instead of needing `Sync`); the original is
    /// dropped before `server_loop` starts, which lets a channel-backed
    /// server loop detect end-of-input when every worker has finished.
    ///
    /// Returns each item's `(client_id, session, job result)` in submission
    /// order plus the server loop's result. A session is `None` only if its
    /// worker panicked — in which case the panic propagates out of this
    /// call once the server loop has returned.
    pub fn run_with_server<S, T, R, Job, Server>(
        &self,
        items: Vec<(usize, S)>,
        job: Job,
        server_loop: Server,
    ) -> (Vec<(usize, Option<S>, Result<T>)>, R)
    where
        S: Send,
        T: Send,
        Job: FnMut(usize, usize, &mut S) -> Result<T> + Send + Clone,
        Server: FnOnce() -> R,
    {
        let n = items.len();
        let slots: Vec<Mutex<Slot<S, T>>> = items
            .into_iter()
            .map(|(id, sess)| {
                Mutex::new(Slot {
                    id,
                    sess: Some(sess),
                    out: None,
                })
            })
            .collect();
        let next = AtomicUsize::new(0);

        let server_result = std::thread::scope(|scope| {
            for _ in 0..self.threads.min(n) {
                let mut job = job.clone();
                let slots = &slots;
                let next = &next;
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let (id, mut sess) = {
                        let mut slot = slots[i].lock().unwrap();
                        let sess = slot.sess.take().expect("job slot claimed twice");
                        (slot.id, sess)
                    };
                    // Train/encode outside the slot lock; other workers are
                    // busy with their own slots.
                    let out = job(i, id, &mut sess);
                    let mut slot = slots[i].lock().unwrap();
                    slot.sess = Some(sess);
                    slot.out = Some(out);
                });
            }
            // Drop the original job so worker-held resources (e.g. the root
            // transport sender inside it) die with the workers.
            drop(job);
            server_loop()
        });

        let finished = slots
            .into_iter()
            .map(|m| {
                let slot = m.into_inner().unwrap();
                let out = slot
                    .out
                    .unwrap_or_else(|| Err(anyhow!("client {} job never ran", slot.id)));
                (slot.id, slot.sess, out)
            })
            .collect();
        (finished, server_result)
    }

    /// Convenience wrapper when there is no concurrent server loop.
    pub fn run<S, T, Job>(
        &self,
        items: Vec<(usize, S)>,
        job: Job,
    ) -> Vec<(usize, Option<S>, Result<T>)>
    where
        S: Send,
        T: Send,
        Job: FnMut(usize, usize, &mut S) -> Result<T> + Send + Clone,
    {
        self.run_with_server(items, job, || ()).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_every_item_once_and_restores_state() {
        let items: Vec<(usize, u64)> = (0..37).map(|i| (i, i as u64 * 10)).collect();
        let calls = AtomicUsize::new(0);
        let pool = ClientPool::new(4);
        let out = pool.run(items, |slot, id, sess: &mut u64| {
            calls.fetch_add(1, Ordering::Relaxed);
            *sess += 1;
            assert_eq!(slot, id, "submission order preserved");
            Ok(*sess)
        });
        assert_eq!(calls.load(Ordering::Relaxed), 37);
        assert_eq!(out.len(), 37);
        for (id, sess, res) in out {
            assert_eq!(sess, Some(id as u64 * 10 + 1));
            assert_eq!(res.unwrap(), id as u64 * 10 + 1);
        }
    }

    #[test]
    fn job_errors_are_per_item_not_fatal() {
        let items: Vec<(usize, ())> = (0..8).map(|i| (i, ())).collect();
        let pool = ClientPool::new(3);
        let out = pool.run(items, |_slot, id, _s: &mut ()| {
            if id % 2 == 0 {
                Err(anyhow!("client {id} boom"))
            } else {
                Ok(id)
            }
        });
        for (id, sess, res) in out {
            assert!(sess.is_some(), "sessions survive job errors");
            assert_eq!(res.is_err(), id % 2 == 0);
        }
    }

    #[test]
    fn server_loop_runs_concurrently_on_caller_thread() {
        use std::sync::mpsc;
        let (tx, rx) = mpsc::channel::<usize>();
        let items: Vec<(usize, ())> = (0..16).map(|i| (i, ())).collect();
        let pool = ClientPool::sized_for(16);
        let tx2 = tx.clone();
        let (results, seen) = pool.run_with_server(
            items,
            move |_slot, id, _s: &mut ()| {
                tx2.send(id).map_err(|_| anyhow!("closed"))?;
                Ok(())
            },
            move || {
                drop(tx); // only worker clones keep the channel open
                let mut got: Vec<usize> = rx.iter().collect();
                got.sort_unstable();
                got
            },
        );
        assert_eq!(seen, (0..16).collect::<Vec<_>>());
        assert!(results.iter().all(|(_, s, _)| s.is_some()));
    }

    #[test]
    #[should_panic]
    fn worker_panic_propagates() {
        let items: Vec<(usize, ())> = (0..4).map(|i| (i, ())).collect();
        ClientPool::new(2).run(items, |_slot, id, _s: &mut ()| {
            if id == 2 {
                panic!("worker died");
            }
            Ok(())
        });
    }
}
