//! `deltamask` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   train         run one federated experiment (method × dataset × settings)
//!   serve         host the coordinator half of an experiment on a socket
//!   client-fleet  connect the training half to a running `serve`
//!   shard-worker  host remote absorb lanes for a coordinator's --shard-place
//!   sweep         run a method sweep over datasets and print a paper-style table
//!   filters       micro-benchmark the probabilistic filters (Table 4 regime)
//!   info          print manifest / artifact status
//!
//! Examples:
//!   deltamask train --method deltamask --dataset cifar100 --rounds 30
//!   deltamask train --backend xla --arch test --dataset cifar10
//!   deltamask train --pipeline batch --method fedpm   (A/B the old barrier)
//!   deltamask train --decode-workers 8    (shard server decode; 0 = cores)
//!   deltamask train --agg-shards 4   (shard aggregation by dimension; 0 = cores)
//!   deltamask train --persistent-pipeline --decode-workers 4 --agg-shards 4
//!       (round-resident workers/lanes/pools: spawn once, park between rounds)
//!   deltamask train --quorum 0.8 --round-deadline-ms 5000 --on-decode-error skip
//!       (fault-tolerant completion: finish degraded over ⌈0.8·K⌉ survivors)
//!   deltamask train --chaos seed=7,drop=0.1,straggle=0.2 --quorum 0.6
//!       (deterministic churn injection — same seed, same faults, every run)
//!   deltamask train --transport uds
//!       (route every update through the framed socket transport, loopback)
//!   deltamask serve --transport uds --listen /tmp/dm.sock --rounds 30
//!   deltamask client-fleet --transport uds --connect /tmp/dm.sock --rounds 30
//!       (two OS processes, same config both sides; also tcp + host:port)
//!   deltamask shard-worker --transport uds --listen /tmp/dm-s1.sock
//!   deltamask train --agg-shards 2 --shard-place local,uds:/tmp/dm-s1.sock
//!       (multi-host shard fabric: absorb lane 1 runs in the worker process,
//!        bitwise identical to the all-local --agg-shards 2 run)
//!   deltamask sweep --datasets cifar10,svhn --methods deltamask,fedpm
//!   deltamask filters --entries 100000
//!
//! Every tuning knob above is one row of the declarative knob table in
//! `fl::knobs` — the single source of truth pairing each `--flag` with its
//! `DELTAMASK_*` environment spelling.
//!
//! The layer map and round lifecycle behind these commands are documented
//! in docs/ARCHITECTURE.md; how the server scaling knobs compose is
//! docs/SCALING.md.

use deltamask::bench::Table;
use deltamask::fl::metrics::ExperimentResult;
use deltamask::fl::{knobs, remote, run_experiment, BackendKind, ExperimentConfig, HeadInit};
use deltamask::util::cli::Args;

// Field-by-field assignment is the point here: the env layer must resolve
// before the CLI layer, so a struct literal cannot express the config.
#[allow(clippy::field_reassign_with_default)]
fn parse_cfg(args: &Args) -> ExperimentConfig {
    // Layer 1+2: hard paper defaults with every DELTAMASK_* env spelling
    // already resolved (ExperimentConfig::default() walks the knob table).
    let mut cfg = ExperimentConfig::default();
    // Experiment-shape options — CLI-only, no env spellings.
    cfg.dataset = args.get_or("dataset", "cifar100").to_string();
    cfg.arch = args.get_or("arch", "vitb32").to_string();
    cfg.n_clients = args.usize("clients", 10);
    cfg.rounds = args.usize("rounds", 30);
    cfg.rho = args.f64("rho", 1.0);
    cfg.local_epochs = args.usize("epochs", 1);
    cfg.samples_per_client = args.usize("samples", 64);
    cfg.test_samples = args.usize("test-samples", 512);
    cfg.dirichlet_alpha = args.f64("alpha", 10.0);
    cfg.kappa0 = args.f64("kappa", 0.8);
    cfg.kappa_floor = args.f64("kappa-floor", 0.25);
    cfg.seed = args.u64("seed", 42);
    cfg.eval_every = args.usize("eval-every", 5);
    cfg.backend = if args.get_or("backend", "native") == "xla" {
        BackendKind::Xla
    } else {
        BackendKind::Native
    };
    cfg.head_init = match args.get_or("head-init", "lp") {
        "he" => HeadInit::He,
        "fit" => HeadInit::Fit,
        _ => HeadInit::Lp,
    };
    cfg.lp_rounds = args.usize("lp-rounds", 1);
    cfg.theta0 = args.f64("theta0", 0.85) as f32;
    // Layer 3: every operator knob's CLI spelling, from the same table
    // that resolved the env layer — parsing and validation live there.
    knobs::apply_cli(&mut cfg, args);
    if let Some(w) = args.get("width") {
        let w: usize = w.parse().expect("--width must be an integer");
        cfg = cfg.miniaturize(w, args.usize("batch", 8));
    }
    cfg
}

fn print_banner(verb: &str, cfg: &ExperimentConfig) {
    eprintln!(
        "{verb}: method={} dataset={} arch={} d={} N={} R={} rho={} alpha={} backend={:?} pipeline={} decode_workers={} agg_shards={} shard_place={} persistent_pipeline={} quorum={} round_deadline_ms={} on_decode_error={} chaos={} transport={}",
        cfg.method,
        cfg.dataset,
        cfg.arch,
        cfg.arch_config().d(),
        cfg.n_clients,
        cfg.rounds,
        cfg.rho,
        cfg.dirichlet_alpha,
        cfg.backend,
        cfg.tuning.pipeline.as_str(),
        cfg.tuning.decode_workers,
        cfg.tuning.agg_shards,
        if cfg.tuning.shard_place.is_empty() { "local" } else { &cfg.tuning.shard_place },
        cfg.tuning.persistent_pipeline,
        cfg.tuning.quorum,
        cfg.tuning.round_deadline_ms,
        cfg.tuning.on_decode_error.as_str(),
        if cfg.chaos.is_empty() { "off" } else { &cfg.chaos },
        cfg.transport.as_str()
    );
}

/// Per-round lines, the final summary line, and the optional `--out` JSON
/// dump — shared by `train` and `serve` so a two-process run is inspected
/// exactly like an in-process one.
fn print_result(args: &Args, res: &ExperimentResult) -> anyhow::Result<()> {
    for r in &res.rounds {
        if let Some(acc) = r.accuracy {
            eprintln!(
                "round {:4}  loss {:.4}  bpp {:.3}  acc {:.4}",
                r.round, r.train_loss, r.mean_bpp, acc
            );
        }
    }
    println!(
        "final: acc={:.4} peak={:.4} avg_bpp={:.4} uplink={:.2} MiB enc={:.2} ms dec={:.2} ms wall={:.1}s",
        res.final_accuracy(),
        res.peak_accuracy(),
        res.avg_bpp(),
        res.total_uplink_mib(),
        res.mean_enc_ms(),
        res.mean_dec_ms(),
        res.wall_secs
    );
    if let Some(out) = args.get("out") {
        std::fs::write(out, res.to_json().to_string_pretty())?;
        eprintln!("wrote {out}");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let cfg = parse_cfg(args);
    print_banner("training", &cfg);
    let res = run_experiment(&cfg)?;
    print_result(args, &res)
}

/// Host the coordinator half of a two-process experiment. Both processes
/// must be launched with the same experiment options; the handshake
/// fingerprint rejects mismatches.
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let cfg = parse_cfg(args);
    let listen = args
        .get("listen")
        .ok_or_else(|| anyhow::anyhow!("serve needs --listen <addr|path>"))?;
    print_banner("serving", &cfg);
    let res = remote::serve_experiment(&cfg, listen)?;
    print_result(args, &res)
}

/// Run the training half of a two-process experiment against a `serve`.
fn cmd_client_fleet(args: &Args) -> anyhow::Result<()> {
    let cfg = parse_cfg(args);
    let connect = args
        .get("connect")
        .ok_or_else(|| anyhow::anyhow!("client-fleet needs --connect <addr|path>"))?;
    let conns = args.usize("connections", 4);
    print_banner("fleet", &cfg);
    remote::run_client_fleet(&cfg, connect, conns)?;
    eprintln!("fleet: coordinator shut the experiment down cleanly");
    Ok(())
}

/// Host one or more remote absorb lanes: a coordinator whose
/// `--shard-place` names this worker's socket ships its shard slice here
/// at round start and drains record splits into it over the DMW1 wire.
/// Both processes must agree on the experiment options; the shard-hello
/// fingerprint rejects mismatches. `--linger` keeps the worker alive for
/// further coordinator sessions (the CI matrix reuses one pair of workers
/// across whole test suites).
fn cmd_shard_worker(args: &Args) -> anyhow::Result<()> {
    let cfg = parse_cfg(args);
    let listen = args
        .get("listen")
        .ok_or_else(|| anyhow::anyhow!("shard-worker needs --listen <addr|path>"))?;
    print_banner("shard-worker", &cfg);
    remote::run_shard_worker(&cfg, listen, args.flag("linger"))
}

fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    let datasets: Vec<&str> = args.get_or("datasets", "cifar10,cifar100").split(',').collect();
    let methods: Vec<&str> = args
        .get_or("methods", "linear_probing,fine_tuning,fedpm,deltamask")
        .split(',')
        .collect();
    let mut table = Table::new(
        "sweep",
        &["method", "dataset", "acc", "avg_bpp", "uplink MiB"],
    );
    for method in &methods {
        for dataset in &datasets {
            let mut a2 = args.clone();
            a2.options.insert("method".into(), method.to_string());
            a2.options.insert("dataset".into(), dataset.to_string());
            let cfg = parse_cfg(&a2);
            let res = run_experiment(&cfg)?;
            table.row(vec![
                method.to_string(),
                dataset.to_string(),
                format!("{:.4}", res.final_accuracy()),
                format!("{:.4}", res.avg_bpp()),
                format!("{:.2}", res.total_uplink_mib()),
            ]);
        }
    }
    table.print();
    if let Some(out) = args.get("out") {
        std::fs::write(out, table.to_json().to_string_pretty())?;
    }
    Ok(())
}

fn cmd_filters(args: &Args) -> anyhow::Result<()> {
    use deltamask::bench::{summarize, time_fn};
    use deltamask::filters::{BinaryFuse, BloomFilter, MembershipFilter, XorFilter};
    use deltamask::util::rng::Xoshiro256pp;
    let n = args.usize("entries", 100_000);
    let mut rng = Xoshiro256pp::new(1);
    let keys: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
    let mut table = Table::new(
        "probabilistic filters",
        &["filter", "bpe", "construct ms", "query ns/key", "fp rate"],
    );
    macro_rules! bench_filter {
        ($label:expr, $build:expr) => {{
            let build_t = summarize(&time_fn(1, 3, || $build));
            let f = $build;
            let queries: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let q_t = summarize(&time_fn(1, 3, || {
                queries.iter().filter(|&&k| f.contains(k)).count()
            }));
            let fp = queries.iter().filter(|&&k| f.contains(k)).count() as f64 / n as f64;
            table.row(vec![
                $label.to_string(),
                format!("{:.2}", f.bits_per_entry()),
                format!("{:.1}", build_t.mean * 1e3),
                format!("{:.1}", q_t.mean / n as f64 * 1e9),
                format!("{:.2e}", fp),
            ]);
        }};
    }
    bench_filter!("bfuse8", BinaryFuse::<u8, 4>::build(&keys).unwrap());
    bench_filter!("bfuse16", BinaryFuse::<u16, 4>::build(&keys).unwrap());
    bench_filter!("bfuse32", BinaryFuse::<u32, 4>::build(&keys).unwrap());
    bench_filter!("xor8", XorFilter::<u8>::build(&keys).unwrap());
    bench_filter!("xor16", XorFilter::<u16>::build(&keys).unwrap());
    bench_filter!("xor32", XorFilter::<u32>::build(&keys).unwrap());
    bench_filter!("bloom8.6", BloomFilter::with_bits_per_entry(&keys, 8.62));
    table.print();
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    match deltamask::runtime::artifacts_dir() {
        Some(dir) => {
            let m = deltamask::runtime::Manifest::load(&dir)?;
            println!("artifacts: {}", dir.display());
            println!("datasets: {:?}", m.datasets.keys().collect::<Vec<_>>());
            for c in &m.combos {
                println!(
                    "  {} C={} F={} B={} d={} graphs={:?}",
                    c.arch,
                    c.c,
                    c.f,
                    c.b,
                    c.d,
                    c.graphs.keys().collect::<Vec<_>>()
                );
            }
        }
        None => println!("no artifacts found — run `make artifacts`"),
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("serve") => cmd_serve(&args),
        Some("client-fleet") => cmd_client_fleet(&args),
        Some("shard-worker") => cmd_shard_worker(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("filters") => cmd_filters(&args),
        Some("info") => cmd_info(),
        _ => {
            eprintln!(
                "usage: deltamask <train|serve|client-fleet|shard-worker|sweep|filters|info> [--options]\n\
                 see `rust/src/main.rs` header for examples"
            );
            Ok(())
        }
    }
}
