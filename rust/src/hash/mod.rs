//! Hashing primitives for the probabilistic filters.
//!
//! The paper's binary fuse filters hash with MurmurHash3 (Appleby 2016,
//! cited in §3.1); the Graf–Lemire reference implementation uses the
//! Murmur3 64-bit *finalizer* over `key + seed` for integer keys. Both are
//! provided: [`murmur3`] for byte strings and [`mix64`]/[`mix_split`] for
//! the u64 index keys the DeltaMask codec actually transmits.

pub mod murmur3;

/// Murmur3 64-bit finalizer (a.k.a. `fmix64`) — full-avalanche bijection.
#[inline]
pub fn mix64(mut h: u64) -> u64 {
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51afd7ed558ccd);
    h ^= h >> 33;
    h = h.wrapping_mul(0xc4ceb9fe1a85ec53);
    h ^= h >> 33;
    h
}

/// Seeded integer hash used by the filters (Graf–Lemire `mix_split`).
#[inline]
pub fn mix_split(key: u64, seed: u64) -> u64 {
    mix64(key.wrapping_add(seed))
}

/// 128→64 multiply-high, used to map a hash to a segment range without
/// modulo bias (Lemire's fast range reduction).
#[inline]
pub fn mulhi(a: u64, b: u64) -> u64 {
    (((a as u128) * (b as u128)) >> 64) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix64_is_bijective_on_sample() {
        // A bijection never collides; check a decent sample.
        let mut seen = std::collections::HashSet::new();
        for i in 0..100_000u64 {
            assert!(seen.insert(mix64(i)));
        }
    }

    #[test]
    fn mix64_avalanche() {
        // Flipping one input bit should flip ~32 output bits on average.
        let mut total = 0u32;
        let n = 1000u64;
        for i in 0..n {
            let x = mix64(i.wrapping_mul(0x9e3779b97f4a7c15));
            let h0 = mix64(x);
            for bit in 0..64 {
                let h1 = mix64(x ^ (1u64 << bit));
                total += (h0 ^ h1).count_ones();
            }
        }
        let avg = total as f64 / (n * 64) as f64;
        assert!((avg - 32.0).abs() < 1.0, "avalanche avg={avg}");
    }

    #[test]
    fn mulhi_basics() {
        assert_eq!(mulhi(u64::MAX, u64::MAX), u64::MAX - 1);
        assert_eq!(mulhi(0, 12345), 0);
        assert_eq!(mulhi(1u64 << 63, 2), 1);
        // mulhi(h, n) < n for all h — the range-reduction invariant.
        for h in [0u64, 1, u64::MAX, 0xdeadbeef] {
            assert!(mulhi(h, 1000) < 1000);
        }
    }
}
