//! Cross-backend integration tests: the AOT-compiled XLA graphs (L2 JAX +
//! L1 Pallas, loaded through PJRT) must agree with the pure-rust native
//! backend on every graph family. This closes the correctness loop:
//!   python ref.py ⇔ pallas kernels ⇔ HLO text ⇔ PJRT execution ⇔ native rust.
//!
//! Requires `make artifacts` (the miniature `test` combo) and a build with
//! the `xla` cargo feature; without it this suite compiles to nothing.

#![cfg(feature = "xla")]

use deltamask::model::backend::{Backend, FtState, LpState};
use deltamask::model::{init_params, ArchConfig, MaskState};
use deltamask::native::NativeBackend;
use deltamask::runtime::{Executor, XlaBackend};
use deltamask::util::rng::Xoshiro256pp;
use std::sync::Arc;

const CFG: ArchConfig = ArchConfig {
    f: 32,
    c: 10,
    b: 8,
    l: 5,
};

fn xla_backend() -> XlaBackend {
    let exec = Arc::new(
        Executor::from_artifacts().expect("run `make artifacts` before `cargo test`"),
    );
    XlaBackend::new(exec, "test", 10).expect("test combo missing from manifest")
}

fn batch(seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Xoshiro256pp::new(seed);
    let mut protos = vec![0.0f32; CFG.c * CFG.f];
    rng.fill_gaussian_f32(&mut protos, 0.0, 1.0);
    let mut x = vec![0.0f32; CFG.b * CFG.f];
    let mut y1h = vec![0.0f32; CFG.b * CFG.c];
    for i in 0..CFG.b {
        let y = rng.below(CFG.c as u64) as usize;
        y1h[i * CFG.c + y] = 1.0;
        for j in 0..CFG.f {
            x[i * CFG.f + j] = protos[y * CFG.f + j] + 0.1 * rng.next_gaussian() as f32;
        }
    }
    (x, y1h)
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    let mut worst = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        worst = worst.max((x - y).abs() / (1.0 + x.abs().max(y.abs())));
    }
    assert!(worst < tol, "{what}: worst rel err {worst}");
}

#[test]
fn eval_parity() {
    let xla = xla_backend();
    let native = NativeBackend;
    let params = init_params(CFG, 1);
    let (x, _) = batch(2);
    let mut rng = Xoshiro256pp::new(3);
    let mask: Vec<f32> = (0..CFG.d())
        .map(|_| if rng.next_f32() < 0.5 { 1.0 } else { 0.0 })
        .collect();
    let a = xla.eval_logits(&params, &mask, &x).unwrap();
    let b = native.eval_logits(&params, &mask, &x).unwrap();
    assert_close(&a, &b, 1e-4, "eval logits");
}

#[test]
fn train_step_parity_over_multiple_steps() {
    let xla = xla_backend();
    let native = NativeBackend;
    let params = init_params(CFG, 4);
    let mut st_x = MaskState::new(CFG.d());
    let mut st_n = MaskState::new(CFG.d());
    let mut rng = Xoshiro256pp::new(5);
    let mut u = vec![0.0f32; CFG.d()];
    for step in 0..5 {
        let (x, y1h) = batch(100 + step);
        rng.fill_f32_uniform(&mut u);
        let la = xla.train_step(&params, &mut st_x, &x, &y1h, &u).unwrap();
        let lb = native.train_step(&params, &mut st_n, &x, &y1h, &u).unwrap();
        assert!(
            (la - lb).abs() < 1e-3 * (1.0 + la.abs()),
            "step {step}: loss {la} vs {lb}"
        );
    }
    assert_close(&st_x.s, &st_n.s, 5e-3, "scores after 5 steps");
    assert_close(&st_x.mt, &st_n.mt, 5e-3, "adam m");
}

#[test]
fn lp_step_parity() {
    let xla = xla_backend();
    let native = NativeBackend;
    let params = init_params(CFG, 6);
    let mut lp_x = LpState::from_params(&params);
    let mut lp_n = LpState::from_params(&params);
    for step in 0..5 {
        let (x, y1h) = batch(200 + step);
        let la = xla.lp_step(&params, &mut lp_x, &x, &y1h).unwrap();
        let lb = native.lp_step(&params, &mut lp_n, &x, &y1h).unwrap();
        assert!((la - lb).abs() < 1e-3 * (1.0 + la.abs()), "step {step}");
    }
    assert_close(&lp_x.head_w, &lp_n.head_w, 1e-3, "lp head");
}

#[test]
fn ft_step_parity() {
    let xla = xla_backend();
    let native = NativeBackend;
    let params = init_params(CFG, 7);
    let mut ft_x = FtState::from_params(&params);
    let mut ft_n = FtState::from_params(&params);
    for step in 0..3 {
        let (x, y1h) = batch(300 + step);
        let la = xla.ft_step(&params, &mut ft_x, &x, &y1h).unwrap();
        let lb = native.ft_step(&params, &mut ft_n, &x, &y1h).unwrap();
        assert!((la - lb).abs() < 1e-3 * (1.0 + la.abs()), "step {step}");
    }
    assert_close(&ft_x.w_blocks, &ft_n.w_blocks, 1e-3, "ft weights");
    let (x, _) = batch(999);
    let ea = xla.ft_eval_logits(&params, &ft_x, &x).unwrap();
    let eb = native.ft_eval_logits(&params, &ft_n, &x).unwrap();
    assert_close(&ea, &eb, 1e-3, "ft eval");
}

#[test]
fn manifest_lists_all_paper_combos() {
    let exec = Executor::from_artifacts().unwrap();
    let m = exec.manifest();
    for (arch, c) in [
        ("vitb32", 10),
        ("vitb32", 49),
        ("vitb32", 100),
        ("vitb32", 101),
        ("vitb32", 196),
        ("vitl14", 100),
        ("dinov2b", 100),
        ("dinov2s", 100),
        ("convmixer", 100),
    ] {
        assert!(m.find(arch, c).is_some(), "missing combo {arch}/{c}");
    }
    assert_eq!(m.datasets.len(), 8, "paper evaluates 8 datasets");
}
