"""L1 correctness: Pallas kernels vs the pure-jnp oracles.

This is the core build-time correctness signal — hypothesis sweeps shapes
and tile sizes and asserts allclose against ref.py for all three kernels
plus the full custom-vjp wiring.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import masked_linear as K
from compile.kernels import ref


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


def rand_mask(rng, *shape):
    return jnp.asarray((rng.uniform(size=shape) < 0.5).astype(np.float32))


# Dims constrained to multiples so every tile choice divides exactly.
dims = st.sampled_from([8, 16, 24, 32, 48, 64])
tiles = st.sampled_from([None, 8, 16])


@settings(max_examples=40, deadline=None)
@given(B=dims, Fin=dims, Fout=dims, bm=tiles, bn=tiles, bk=tiles, seed=st.integers(0, 2**31 - 1))
def test_masked_matmul_matches_ref(B, Fin, Fout, bm, bn, bk, seed):
    rng = np.random.default_rng(seed)
    x, w, m = rand(rng, B, Fin), rand(rng, Fout, Fin), rand_mask(rng, Fout, Fin)
    got = K.masked_matmul(x, w, m, bm=bm, bn=bn, bk=bk)
    want = ref.masked_matmul_ref(x, w, m)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(B=dims, Fin=dims, Fout=dims, bm=tiles, bn=tiles, bk=tiles, seed=st.integers(0, 2**31 - 1))
def test_masked_matmul_rhs_matches_ref(B, Fin, Fout, bm, bn, bk, seed):
    rng = np.random.default_rng(seed)
    dy, w, m = rand(rng, B, Fout), rand(rng, Fout, Fin), rand_mask(rng, Fout, Fin)
    got = K.masked_matmul_rhs(dy, w, m, bm=bm, bn=bn, bk=bk)
    want = ref.masked_matmul_rhs_ref(dy, w, m)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(B=dims, Fin=dims, Fout=dims, bm=tiles, bn=tiles, bk=tiles, seed=st.integers(0, 2**31 - 1))
def test_masked_outer_matches_ref(B, Fin, Fout, bm, bn, bk, seed):
    rng = np.random.default_rng(seed)
    dy, x, w = rand(rng, B, Fout), rand(rng, B, Fin), rand(rng, Fout, Fin)
    got = K.masked_outer(dy, x, w, bm=bm, bn=bn, bk=bk)
    want = ref.masked_outer_ref(dy, x, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_masked_linear_vjp_matches_autodiff_of_ref(seed):
    """The custom_vjp wiring must equal autodiff of the reference."""
    rng = np.random.default_rng(seed)
    B, Fin, Fout = 16, 32, 24
    x, w, m = rand(rng, B, Fin), rand(rng, Fout, Fin), rand_mask(rng, Fout, Fin)
    dy = rand(rng, B, Fout)

    y, vjp = jax.vjp(K.masked_linear, x, w, m)
    dx, dw, dm = vjp(dy)

    y_ref, vjp_ref = jax.vjp(ref.masked_matmul_ref, x, w, m)
    dx_ref, dw_ref, dm_ref = vjp_ref(dy)

    np.testing.assert_allclose(y, y_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(dx, dx_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(dm, dm_ref, rtol=1e-4, atol=1e-4)
    # Frozen weights: our kernel returns exactly zero for dw.
    np.testing.assert_array_equal(np.asarray(dw), 0.0)


def test_zero_mask_kills_output():
    rng = np.random.default_rng(0)
    x, w = rand(rng, 8, 16), rand(rng, 16, 16)
    y = K.masked_matmul(x, w, jnp.zeros_like(w))
    np.testing.assert_array_equal(np.asarray(y), 0.0)


def test_ones_mask_is_plain_matmul():
    rng = np.random.default_rng(1)
    x, w = rand(rng, 8, 16), rand(rng, 16, 16)
    y = K.masked_matmul(x, w, jnp.ones_like(w))
    np.testing.assert_allclose(y, x @ w.T, rtol=1e-5, atol=1e-6)


def test_best_tile_divides():
    for dim in [8, 32, 64, 160, 256, 288, 320, 384, 101, 49]:
        t = K.best_tile(dim)
        assert dim % t == 0
        assert t <= K.TILE_CAP


def test_vmem_budget_for_all_archs():
    """Structural perf check (DESIGN.md §8): every lowered tile config must
    fit far below a 16 MiB VMEM budget."""
    for F in [160, 256, 288, 320, 384]:
        bm, bn, bk = K.best_tile(64), K.best_tile(F), K.best_tile(F)
        assert K.vmem_bytes(bm, bn, bk) < 2 * 2**20, (F, bm, bn, bk)


def test_mxu_utilization_reported():
    # 128-divisible widths keep the MXU fully busy; smaller widths degrade
    # gracefully and are reported, not hidden.
    assert K.mxu_utilization_estimate(128, 128, 128) == 1.0
    assert 0.0 < K.mxu_utilization_estimate(64, 80, 96) < 1.0
