//! Pure-rust reference backend: mirrors the L2 JAX graphs (and therefore
//! the L1 Pallas kernels) op-for-op, with hand-written gradients.
//!
//! Purpose:
//! 1. **Cross-check** — `rust/tests/backend_parity.rs` asserts XLA-vs-native
//!    allclose on every graph, closing the loop python-ref → pallas → HLO →
//!    PJRT → native.
//! 2. **Sweep engine** — for the miniature simulated FMs, a tight native
//!    matmul beats XLA interpret-mode dispatch overhead, making the full
//!    Table 2/3 sweeps tractable on CPU.

pub mod linalg;

use crate::model::backend::{adam, Backend, FtState, LpState, ModelParams};
use crate::model::{sigmoid, ArchConfig, MaskState};
use linalg::{matmul_at, matmul_bt, matmul_nn};

pub struct NativeBackend;

/// Forward through the L masked residual blocks, keeping per-block
/// pre-activations and inputs for the backward pass.
struct ForwardTrace {
    hs: Vec<Vec<f32>>, // h_0 .. h_L, each B·F
    zs: Vec<Vec<f32>>, // z_1 .. z_L (pre-relu), each B·F
}

fn forward_blocks(
    cfg: ArchConfig,
    w_blocks: &[f32],
    masks: &[f32],
    x: &[f32],
    keep_trace: bool,
) -> ForwardTrace {
    let (b, f) = (cfg.b, cfg.f);
    let mut hs = Vec::with_capacity(cfg.l + 1);
    let mut zs = Vec::with_capacity(cfg.l);
    hs.push(x.to_vec());
    let mut mw = vec![0.0f32; f * f];
    for l in 0..cfg.l {
        let w = &w_blocks[l * f * f..(l + 1) * f * f];
        let m = &masks[l * f * f..(l + 1) * f * f];
        for i in 0..f * f {
            mw[i] = w[i] * m[i];
        }
        let h = hs.last().unwrap();
        // z = h @ (m*w)^T : (B,F) x (F,F)^T
        let mut z = vec![0.0f32; b * f];
        matmul_bt(h, &mw, &mut z, b, f, f);
        let mut hnext = h.clone();
        for i in 0..b * f {
            hnext[i] += z[i].max(0.0);
        }
        if keep_trace {
            zs.push(z);
        }
        hs.push(hnext);
        if !keep_trace && hs.len() > 1 {
            hs.remove(0); // keep memory flat in eval mode
        }
    }
    ForwardTrace { hs, zs }
}

fn logits_from_h(cfg: ArchConfig, h: &[f32], head_w: &[f32], head_b: &[f32]) -> Vec<f32> {
    let (b, f, c) = (cfg.b, cfg.f, cfg.c);
    let mut logits = vec![0.0f32; b * c];
    matmul_bt(h, head_w, &mut logits, b, f, c);
    for row in 0..b {
        for j in 0..c {
            logits[row * c + j] += head_b[j];
        }
    }
    logits
}

/// Softmax cross-entropy: returns (loss, dlogits) with dlogits already
/// scaled by 1/B (matching `jnp.mean` in L2).
fn ce_loss_and_grad(logits: &[f32], y_onehot: &[f32], b: usize, c: usize) -> (f32, Vec<f32>) {
    let mut loss = 0.0f64;
    let mut dlogits = vec![0.0f32; b * c];
    for row in 0..b {
        let lr = &logits[row * c..(row + 1) * c];
        let yr = &y_onehot[row * c..(row + 1) * c];
        let maxv = lr.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f64;
        for &v in lr {
            denom += ((v - maxv) as f64).exp();
        }
        let log_denom = denom.ln() as f32 + maxv;
        for j in 0..c {
            let logp = lr[j] - log_denom;
            loss -= (yr[j] * logp) as f64;
            let p = logp.exp();
            dlogits[row * c + j] = (p - yr[j]) / b as f32;
        }
    }
    ((loss / b as f64) as f32, dlogits)
}

impl NativeBackend {
    /// Shared forward+backward producing the mask gradient dL/dm (length d).
    fn mask_grad(
        cfg: ArchConfig,
        params: &ModelParams,
        masks: &[f32],
        x: &[f32],
        y_onehot: &[f32],
    ) -> (f32, Vec<f32>) {
        let (b, f, c) = (cfg.b, cfg.f, cfg.c);
        let trace = forward_blocks(cfg, &params.w_blocks, masks, x, true);
        let h_last = trace.hs.last().unwrap();
        let logits = logits_from_h(cfg, h_last, &params.head_w, &params.head_b);
        let (loss, dlogits) = ce_loss_and_grad(&logits, y_onehot, b, c);

        // dh_L = dlogits @ head_w : (B,C) x (C,F)
        let mut dh = vec![0.0f32; b * f];
        matmul_nn(&dlogits, &params.head_w, &mut dh, b, c, f);

        let mut dmask = vec![0.0f32; cfg.d()];
        let mut dz = vec![0.0f32; b * f];
        let mut mw = vec![0.0f32; f * f];
        for l in (0..cfg.l).rev() {
            let w = &params.w_blocks[l * f * f..(l + 1) * f * f];
            let m = &masks[l * f * f..(l + 1) * f * f];
            let z = &trace.zs[l];
            let h_in = &trace.hs[l];
            // dz = dh ⊙ relu'(z)
            for i in 0..b * f {
                dz[i] = if z[i] > 0.0 { dh[i] } else { 0.0 };
            }
            // dm = (dz^T @ h_in) ⊙ w  : (F,F)
            let dm = &mut dmask[l * f * f..(l + 1) * f * f];
            matmul_at(&dz, h_in, dm, b, f, f);
            for i in 0..f * f {
                dm[i] *= w[i];
            }
            // dh_in = dh + dz @ (m*w) : residual + matmul path
            for i in 0..f * f {
                mw[i] = w[i] * m[i];
            }
            let mut dh_in = vec![0.0f32; b * f];
            matmul_nn(&dz, &mw, &mut dh_in, b, f, f);
            for i in 0..b * f {
                dh_in[i] += dh[i];
            }
            dh = dh_in;
        }
        (loss, dmask)
    }
}

impl Backend for NativeBackend {
    fn train_step(
        &self,
        params: &ModelParams,
        state: &mut MaskState,
        x: &[f32],
        y_onehot: &[f32],
        u: &[f32],
    ) -> anyhow::Result<f32> {
        let cfg = params.cfg;
        let d = cfg.d();
        anyhow::ensure!(state.s.len() == d && u.len() == d);
        // θ = σ(s); m = 1[u < θ] (STE: dL/dθ = dL/dm).
        let mut masks = vec![0.0f32; d];
        let mut theta = vec![0.0f32; d];
        for i in 0..d {
            theta[i] = sigmoid(state.s[i]);
            masks[i] = if u[i] < theta[i] { 1.0 } else { 0.0 };
        }
        let (loss, dmask) = Self::mask_grad(cfg, params, &masks, x, y_onehot);
        // ds = dm ⊙ σ'(s) = dm ⊙ θ(1-θ)
        let mut g = dmask;
        for i in 0..d {
            g[i] *= theta[i] * (1.0 - theta[i]);
        }
        state.step += 1;
        adam::update(
            &mut state.s,
            &g,
            &mut state.mt,
            &mut state.vt,
            state.step,
            adam::MASK_LR,
        );
        Ok(loss)
    }

    fn eval_logits(
        &self,
        params: &ModelParams,
        mask: &[f32],
        x: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        let cfg = params.cfg;
        let trace = forward_blocks(cfg, &params.w_blocks, mask, x, false);
        Ok(logits_from_h(
            cfg,
            trace.hs.last().unwrap(),
            &params.head_w,
            &params.head_b,
        ))
    }

    fn lp_step(
        &self,
        params: &ModelParams,
        state: &mut LpState,
        x: &[f32],
        y_onehot: &[f32],
    ) -> anyhow::Result<f32> {
        let cfg = params.cfg;
        let (b, f, c) = (cfg.b, cfg.f, cfg.c);
        let ones = vec![1.0f32; cfg.d()];
        let trace = forward_blocks(cfg, &params.w_blocks, &ones, x, false);
        let h = trace.hs.last().unwrap();
        let logits = logits_from_h(cfg, h, &state.head_w, &state.head_b);
        let (loss, dlogits) = ce_loss_and_grad(&logits, y_onehot, b, c);
        // g_hw = dlogits^T @ h : (C,F); g_hb = column sums of dlogits.
        let mut g_hw = vec![0.0f32; c * f];
        matmul_at(&dlogits, h, &mut g_hw, b, c, f);
        let mut g_hb = vec![0.0f32; c];
        for row in 0..b {
            for j in 0..c {
                g_hb[j] += dlogits[row * c + j];
            }
        }
        state.step += 1;
        let t = state.step;
        adam::update(&mut state.head_w, &g_hw, &mut state.m_hw, &mut state.v_hw, t, adam::LP_LR);
        adam::update(&mut state.head_b, &g_hb, &mut state.m_hb, &mut state.v_hb, t, adam::LP_LR);
        Ok(loss)
    }

    fn ft_step(
        &self,
        params: &ModelParams,
        state: &mut FtState,
        x: &[f32],
        y_onehot: &[f32],
    ) -> anyhow::Result<f32> {
        let cfg = params.cfg;
        let (b, f, c) = (cfg.b, cfg.f, cfg.c);
        let ones = vec![1.0f32; cfg.d()];
        let trace = forward_blocks(cfg, &state.w_blocks, &ones, x, true);
        let h_last = trace.hs.last().unwrap();
        let logits = logits_from_h(cfg, h_last, &state.head_w, &state.head_b);
        let (loss, dlogits) = ce_loss_and_grad(&logits, y_onehot, b, c);

        let mut g_hw = vec![0.0f32; c * f];
        matmul_at(&dlogits, h_last, &mut g_hw, b, c, f);
        let mut g_hb = vec![0.0f32; c];
        for row in 0..b {
            for j in 0..c {
                g_hb[j] += dlogits[row * c + j];
            }
        }
        let mut dh = vec![0.0f32; b * f];
        matmul_nn(&dlogits, &state.head_w, &mut dh, b, c, f);

        let mut g_wb = vec![0.0f32; cfg.d()];
        let mut dz = vec![0.0f32; b * f];
        for l in (0..cfg.l).rev() {
            let w = &state.w_blocks[l * f * f..(l + 1) * f * f];
            let z = &trace.zs[l];
            let h_in = &trace.hs[l];
            for i in 0..b * f {
                dz[i] = if z[i] > 0.0 { dh[i] } else { 0.0 };
            }
            // g_w = dz^T @ h_in (mask ≡ 1)
            let gw = &mut g_wb[l * f * f..(l + 1) * f * f];
            matmul_at(&dz, h_in, gw, b, f, f);
            let mut dh_in = vec![0.0f32; b * f];
            matmul_nn(&dz, w, &mut dh_in, b, f, f);
            for i in 0..b * f {
                dh_in[i] += dh[i];
            }
            dh = dh_in;
        }

        state.step += 1;
        let t = state.step;
        adam::update(&mut state.w_blocks, &g_wb, &mut state.m_wb, &mut state.v_wb, t, adam::FT_LR);
        adam::update(&mut state.head_w, &g_hw, &mut state.m_hw, &mut state.v_hw, t, adam::FT_LR);
        adam::update(&mut state.head_b, &g_hb, &mut state.m_hb, &mut state.v_hb, t, adam::FT_LR);
        Ok(loss)
    }

    fn ft_eval_logits(
        &self,
        params: &ModelParams,
        state: &FtState,
        x: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        let cfg = params.cfg;
        let ones = vec![1.0f32; cfg.d()];
        let trace = forward_blocks(cfg, &state.w_blocks, &ones, x, false);
        Ok(logits_from_h(
            cfg,
            trace.hs.last().unwrap(),
            &state.head_w,
            &state.head_b,
        ))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{init_params, ArchConfig, MaskState};
    use crate::util::rng::Xoshiro256pp;

    fn cfg() -> ArchConfig {
        ArchConfig::new(32, 10, 8, 5)
    }

    fn batch(cfg: ArchConfig, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<u32>) {
        let mut rng = Xoshiro256pp::new(seed);
        // Separable: per-class prototypes + small noise.
        let mut protos = vec![0.0f32; cfg.c * cfg.f];
        rng.fill_gaussian_f32(&mut protos, 0.0, 1.0);
        let mut x = vec![0.0f32; cfg.b * cfg.f];
        let mut y1h = vec![0.0f32; cfg.b * cfg.c];
        let mut labels = vec![0u32; cfg.b];
        for i in 0..cfg.b {
            let y = rng.below(cfg.c as u64) as usize;
            labels[i] = y as u32;
            y1h[i * cfg.c + y] = 1.0;
            for j in 0..cfg.f {
                x[i * cfg.f + j] =
                    protos[y * cfg.f + j] + 0.1 * rng.next_gaussian() as f32;
            }
        }
        (x, y1h, labels)
    }

    #[test]
    fn train_decreases_loss() {
        let cfg = cfg();
        let params = init_params(cfg, 1);
        let backend = NativeBackend;
        let mut state = MaskState::new(cfg.d());
        let (x, y1h, _) = batch(cfg, 2);
        let mut rng = Xoshiro256pp::new(3);
        let mut losses = Vec::new();
        let mut u = vec![0.0f32; cfg.d()];
        for _ in 0..30 {
            rng.fill_f32_uniform(&mut u);
            losses.push(backend.train_step(&params, &mut state, &x, &y1h, &u).unwrap());
        }
        assert!(
            losses[29] < losses[0] * 0.9,
            "first={} last={}",
            losses[0],
            losses[29]
        );
    }

    #[test]
    fn lp_trains_head() {
        let cfg = cfg();
        let params = init_params(cfg, 4);
        let backend = NativeBackend;
        let mut lp = crate::model::backend::LpState::from_params(&params);
        let (x, y1h, _) = batch(cfg, 5);
        let first = backend.lp_step(&params, &mut lp, &x, &y1h).unwrap();
        let mut last = first;
        for _ in 0..40 {
            last = backend.lp_step(&params, &mut lp, &x, &y1h).unwrap();
        }
        assert!(last < first * 0.5, "first={first} last={last}");
    }

    #[test]
    fn ft_trains_weights() {
        let cfg = cfg();
        let params = init_params(cfg, 6);
        let backend = NativeBackend;
        let mut ft = crate::model::backend::FtState::from_params(&params);
        let (x, y1h, _) = batch(cfg, 7);
        let first = backend.ft_step(&params, &mut ft, &x, &y1h).unwrap();
        let mut last = first;
        for _ in 0..40 {
            last = backend.ft_step(&params, &mut ft, &x, &y1h).unwrap();
        }
        assert!(last < first * 0.7, "first={first} last={last}");
        assert_ne!(ft.w_blocks, params.w_blocks);
    }

    #[test]
    fn eval_deterministic_and_mask_sensitive() {
        let cfg = cfg();
        let params = init_params(cfg, 8);
        let backend = NativeBackend;
        let (x, _, _) = batch(cfg, 9);
        let ones = vec![1.0f32; cfg.d()];
        let zeros = vec![0.0f32; cfg.d()];
        let a = backend.eval_logits(&params, &ones, &x).unwrap();
        let b = backend.eval_logits(&params, &ones, &x).unwrap();
        assert_eq!(a, b);
        let z = backend.eval_logits(&params, &zeros, &x).unwrap();
        assert_ne!(a, z); // zero mask = identity blocks, different logits
    }

    #[test]
    fn finite_difference_grad_check() {
        // dL/dm from mask_grad vs numeric gradient on a few coordinates.
        let cfg = ArchConfig::new(8, 4, 4, 2);
        let params = init_params(cfg, 10);
        let (x, y1h, _) = batch(cfg, 11);
        let mut rng = Xoshiro256pp::new(12);
        let mut masks = vec![0.0f32; cfg.d()];
        for m in masks.iter_mut() {
            *m = rng.next_f32(); // soft mask exercises the full gradient
        }
        let (_, grad) = NativeBackend::mask_grad(cfg, &params, &masks, &x, &y1h);
        let eps = 1e-3f32;
        for &idx in &[0usize, 7, 63, cfg.d() - 1] {
            let mut mp = masks.clone();
            mp[idx] += eps;
            let (lp, _) = NativeBackend::mask_grad(cfg, &params, &mp, &x, &y1h);
            let mut mm = masks.clone();
            mm[idx] -= eps;
            let (lm, _) = NativeBackend::mask_grad(cfg, &params, &mm, &x, &y1h);
            let numeric = (lp - lm) / (2.0 * eps);
            assert!(
                (numeric - grad[idx]).abs() < 2e-2 * (1.0 + numeric.abs()),
                "idx={idx}: numeric={numeric} analytic={}",
                grad[idx]
            );
        }
    }
}
