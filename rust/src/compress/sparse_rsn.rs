//! **Sparse-RSN** (codec 11) — 1-bit sparse supermasks over fixed random
//! weights, after *Regularized Sparse Random Networks* (arxiv 2309.10834).
//!
//! RSN never trains weights: the network is frozen at its (seed-derived)
//! random initialization and each client learns a binary **supermask**
//! selecting which random weights participate; a sparsity regularizer keeps
//! the supermask small and the server aggregates client supermasks by
//! mean/majority vote. Mapped onto this repo: the frozen random weights are
//! the shared-seed model that every party already derives, the client's
//! supermask is its sampled mask `m^{k,t}` pruned by an **L1-style score
//! penalty** — coordinate `i` stays active only when `m^{k,t}_i = 1` *and*
//! the client posterior clears the penalty, `θ^{k,t}_i ≥ λ` (an entry whose
//! posterior cannot pay the regularizer is dropped even if the Bernoulli
//! draw came up 1) — and the mean/majority aggregation is exactly the Beta
//! pseudo-count server path (`Family::Mask`): the posterior mean over
//! absolute client supermasks *is* their vote average.
//!
//! Unlike the Δ-flip codecs, the record is **absolute**: it reconstructs
//! the client's pruned supermask outright rather than flipping `m^{g,t-1}`.
//! The active set is shipped as a codec-9-style pco index stream with a
//! polarity twist — whichever of the active set or its complement is
//! smaller goes on the wire, so a polarized late-training supermask costs
//! `min(|A|, d−|A|)` gaps, never more than d/2:
//!
//! ```text
//! tag(1)=9  version(1)=1  polarity(1)  payload_len(4)  payload = pco stream
//! ```
//!
//! `polarity = 0`: payload lists the **active** coordinates (base 0.0,
//! listed → 1.0). `polarity = 1`: payload lists the **inactive** ones
//! (base 1.0, listed → 0.0).
//!
//! Decode totality: header fields and polarity are validated, the pco
//! decoder is total and `d`-bounded, and indexes must be strictly
//! increasing and `< d` — corrupt records yield `Err`, never a panic. Range
//! decoding is supported (the record is a per-index property: base value
//! plus membership), with the one contract nuance that the reconstruction
//! **overwrites** the `m^{g,t-1}` baseline the tile was initialized from —
//! tiling still reproduces the full decode bitwise.

use super::{
    wire, DecodeCtx, EncodeCtx, EncodeScratch, Encoded, Family, ScratchPool, Update, UpdateCodec,
};
use crate::codec::pco;
use anyhow::{ensure, Result};

/// Record tag: next free tag after the v1 filter-tag space (0..=6), the
/// codec-9 pco record (7) and the MaskRN record (8).
pub const RECORD_TAG: u8 = 9;
/// Record format version.
pub const RECORD_VERSION: u8 = 1;

/// Default L1-style penalty: an active entry must hold posterior mass
/// `θ^{k,t} ≥ λ` to stay in the supermask. At 0.5 the regularizer prunes
/// exactly the coordinates the client's training has turned against
/// (posterior below a coin flip) while leaving warm entries untouched.
pub const DEFAULT_LAMBDA: f32 = 0.5;

#[derive(Clone, Debug)]
pub struct SparseRsnCodec {
    /// Sparsity penalty λ (see [`DEFAULT_LAMBDA`]). Encoder-side only — the
    /// wire carries the pruned result, so decode needs no λ.
    pub lambda: f32,
}

impl Default for SparseRsnCodec {
    fn default() -> Self {
        Self {
            lambda: DEFAULT_LAMBDA,
        }
    }
}

/// Parsed record: the supermask as (base value, exception index set).
struct ParsedSupermask {
    base: f32,
    idx: Vec<u32>,
}

impl SparseRsnCodec {
    /// Parse + validate a record. Shared by every decode path so
    /// malformed-record rejection is uniform.
    fn parse(&self, bytes: &[u8], ctx: &DecodeCtx) -> Result<ParsedSupermask> {
        ensure!(bytes.len() >= 7, "sparse-rsn record too short");
        ensure!(
            bytes[0] == RECORD_TAG,
            "not a sparse-rsn record (tag {})",
            bytes[0]
        );
        ensure!(
            bytes[1] == RECORD_VERSION,
            "unknown sparse-rsn record version {}",
            bytes[1]
        );
        let polarity = bytes[2];
        ensure!(polarity <= 1, "bad polarity byte {polarity}");
        let mut r = wire::Reader::new(&bytes[3..]);
        let payload_len = r.u32()? as usize;
        let rest = &bytes[3 + r.pos..];
        ensure!(rest.len() == payload_len, "payload length mismatch");
        let idx = pco::decompress_u32s(rest, ctx.d).map_err(|e| anyhow::anyhow!("pco: {e}"))?;
        let mut prev = None;
        for &i in &idx {
            ensure!((i as usize) < ctx.d, "index {i} out of range (d={})", ctx.d);
            if let Some(p) = prev {
                ensure!(i > p, "indexes not strictly increasing");
            }
            prev = Some(i);
        }
        Ok(ParsedSupermask {
            base: polarity as f32,
            idx,
        })
    }

    /// Reconstruct the supermask into `out` (any prior contents are
    /// overwritten — the record is absolute).
    fn fill(&self, parsed: &ParsedSupermask, out: &mut [f32]) {
        out.fill(parsed.base);
        let flip = 1.0 - parsed.base;
        for &i in &parsed.idx {
            out[i as usize] = flip;
        }
    }
}

/// Range decoder: base-fill plus two binary searches per range. Overwrites
/// the baseline the tile was initialized from (absolute reconstruction).
struct SupermaskRange {
    base: f32,
    idx: Vec<u32>,
}

impl super::MaskRangeDecoder for SupermaskRange {
    fn decode_range(&self, range: std::ops::Range<usize>, mask: &mut [f32]) {
        debug_assert_eq!(mask.len(), range.len());
        mask.fill(self.base);
        let flip = 1.0 - self.base;
        let lo = self.idx.partition_point(|&i| (i as usize) < range.start);
        let hi = self.idx.partition_point(|&i| (i as usize) < range.end);
        for &i in &self.idx[lo..hi] {
            mask[i as usize - range.start] = flip;
        }
    }
}

impl UpdateCodec for SparseRsnCodec {
    fn name(&self) -> &'static str {
        "sparse-rsn"
    }

    fn family(&self) -> Family {
        Family::Mask
    }

    fn encode(&self, ctx: &EncodeCtx) -> Result<Encoded> {
        self.encode_with(ctx, &mut EncodeScratch::default())
    }

    /// Encode reusing the caller's scratch: one pass over (m^{k,t}, θ^{k,t})
    /// splits coordinates into active/inactive (both ascending by
    /// construction) in the recycled `delta`/`rank` buffers, then the
    /// smaller side becomes the pco payload — steady-state encodes allocate
    /// only the output bytes.
    fn encode_with(&self, ctx: &EncodeCtx, scratch: &mut EncodeScratch) -> Result<Encoded> {
        ensure!(
            ctx.mask_k.len() == ctx.d && ctx.theta_k.len() == ctx.d,
            "mask/theta length mismatch"
        );
        scratch.delta.clear(); // active coordinates
        scratch.rank.clear(); // inactive coordinates
        for i in 0..ctx.d {
            if ctx.mask_k[i] > 0.5 && ctx.theta_k[i] >= self.lambda {
                scratch.delta.push(i as u32);
            } else {
                scratch.rank.push(i as u32);
            }
        }
        let (polarity, side): (u8, &[u32]) = if scratch.delta.len() <= scratch.rank.len() {
            (0, &scratch.delta)
        } else {
            (1, &scratch.rank)
        };
        let payload = pco::compress_u32s(side);

        let mut bytes = Vec::with_capacity(payload.len() + 7);
        bytes.push(RECORD_TAG);
        bytes.push(RECORD_VERSION);
        bytes.push(polarity);
        wire::put_u32(&mut bytes, payload.len() as u32);
        bytes.extend_from_slice(&payload);
        Ok(Encoded { bytes })
    }

    fn decode(&self, bytes: &[u8], ctx: &DecodeCtx) -> Result<Update> {
        let parsed = self.parse(bytes, ctx)?;
        let mut mask = vec![0.0f32; ctx.d];
        self.fill(&parsed, &mut mask);
        Ok(Update::Mask(mask))
    }

    fn decode_pooled(&self, bytes: &[u8], ctx: &DecodeCtx, pool: &ScratchPool) -> Result<Update> {
        // Parse before leasing, so malformed records never touch the pool.
        let parsed = self.parse(bytes, ctx)?;
        let mut mask = pool.take_copy(ctx.mask_g);
        self.fill(&parsed, &mut mask);
        Ok(Update::Mask(mask))
    }

    fn range_decoder(
        &self,
        bytes: &[u8],
        ctx: &DecodeCtx,
    ) -> Result<Option<Box<dyn super::MaskRangeDecoder>>> {
        let parsed = self.parse(bytes, ctx)?;
        Ok(Some(Box::new(SupermaskRange {
            base: parsed.base,
            idx: parsed.idx,
        })))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::sample_mask_seeded;
    use crate::util::rng::Xoshiro256pp;

    fn make_ctx<'a>(
        d: usize,
        theta_k: &'a [f32],
        theta_g: &'a [f32],
        mask_k: &'a [f32],
        mask_g: &'a [f32],
        kappa: f64,
    ) -> EncodeCtx<'a> {
        EncodeCtx {
            d,
            theta_k,
            theta_g,
            mask_k,
            mask_g,
            s_k: &[],
            s_g: &[],
            kappa,
            seed: 99,
        }
    }

    fn setup(d: usize, drift: f32, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Xoshiro256pp::new(seed);
        let theta_g: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
        let theta_k: Vec<f32> = theta_g
            .iter()
            .map(|&p| (p + drift * (rng.next_f32() - 0.5)).clamp(0.01, 0.99))
            .collect();
        let mut mask_g = Vec::new();
        sample_mask_seeded(&theta_g, 7, &mut mask_g);
        let mut mask_k = Vec::new();
        sample_mask_seeded(&theta_k, 8, &mut mask_k);
        (theta_k, theta_g, mask_k, mask_g)
    }

    /// The supermask the encoder must transmit: m^{k,t} pruned by λ.
    fn expected_supermask(theta_k: &[f32], mask_k: &[f32], lambda: f32) -> Vec<f32> {
        theta_k
            .iter()
            .zip(mask_k)
            .map(|(&t, &m)| if m > 0.5 && t >= lambda { 1.0 } else { 0.0 })
            .collect()
    }

    #[test]
    fn decode_reconstructs_the_penalized_supermask_exactly() {
        let d = 50_000;
        let (tk, tg, mk, mg) = setup(d, 0.2, 42);
        let codec = SparseRsnCodec::default();
        let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 0.6);
        let enc = codec.encode(&ctx).unwrap();
        let dec_ctx = DecodeCtx {
            d,
            mask_g: &mg,
            s_g: &[],
            seed: 99,
        };
        let Update::Mask(m) = codec.decode(&enc.bytes, &dec_ctx).unwrap() else {
            panic!()
        };
        let expect = expected_supermask(&tk, &mk, codec.lambda);
        assert_eq!(m, expect, "decode must equal the λ-pruned client supermask");
        // The penalty must actually prune: some sampled-1 entries with weak
        // posteriors are dropped.
        let pruned = mk
            .iter()
            .zip(&expect)
            .filter(|&(&m, &e)| m > 0.5 && e < 0.5)
            .count();
        assert!(pruned > 0, "λ={} never pruned anything", codec.lambda);
    }

    #[test]
    fn polarity_ships_the_smaller_side() {
        let d = 10_000;
        // Nearly-all-active supermask → polarity 1 (inactive list on wire).
        let theta = vec![0.9f32; d];
        let mut mask_k = vec![1.0f32; d];
        for i in (0..d).step_by(997) {
            mask_k[i] = 0.0;
        }
        let mask_g = vec![0.0f32; d];
        let codec = SparseRsnCodec::default();
        let ctx = make_ctx(d, &theta, &theta, &mask_k, &mask_g, 1.0);
        let enc = codec.encode(&ctx).unwrap();
        assert_eq!(enc.bytes[2], 1, "dense supermask must ship its complement");
        // It still decodes to the exact supermask…
        let dec_ctx = DecodeCtx {
            d,
            mask_g: &mask_g,
            s_g: &[],
            seed: 99,
        };
        let Update::Mask(m) = codec.decode(&enc.bytes, &dec_ctx).unwrap() else {
            panic!()
        };
        assert_eq!(m, expected_supermask(&theta, &mask_k, codec.lambda));
        // …and costs far less than the active list would: the record stays
        // well under 1 bpp even though |A| ≈ d.
        assert!(
            (enc.bytes.len() as f64) * 8.0 / (d as f64) < 1.0,
            "dense supermask record is {} bytes",
            enc.bytes.len()
        );

        // Nearly-all-inactive → polarity 0 (active list on wire).
        let mask_k: Vec<f32> = (0..d).map(|i| if i % 997 == 0 { 1.0 } else { 0.0 }).collect();
        let ctx = make_ctx(d, &theta, &theta, &mask_k, &mask_g, 1.0);
        let enc = codec.encode(&ctx).unwrap();
        assert_eq!(enc.bytes[2], 0, "sparse supermask must ship its active set");
    }

    #[test]
    fn scratch_pooled_and_range_paths_are_identical() {
        let d = 30_000;
        let (tk, tg, mk, mg) = setup(d, 0.1, 43);
        let codec = SparseRsnCodec::default();
        let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 0.8);
        let plain = codec.encode(&ctx).unwrap();
        let mut scratch = EncodeScratch::default();
        let scratched = codec.encode_with(&ctx, &mut scratch).unwrap();
        assert_eq!(plain.bytes, scratched.bytes);
        let again = codec.encode_with(&ctx, &mut scratch).unwrap();
        assert_eq!(plain.bytes, again.bytes);

        let dec_ctx = DecodeCtx {
            d,
            mask_g: &mg,
            s_g: &[],
            seed: 99,
        };
        let Update::Mask(want) = codec.decode(&plain.bytes, &dec_ctx).unwrap() else {
            panic!()
        };
        let pool = ScratchPool::new();
        let Update::Mask(got) = codec.decode_pooled(&plain.bytes, &dec_ctx, &pool).unwrap()
        else {
            panic!()
        };
        assert_eq!(got, want);
        pool.put(got);
        let Update::Mask(got2) = codec.decode_pooled(&plain.bytes, &dec_ctx, &pool).unwrap()
        else {
            panic!()
        };
        assert_eq!(got2, want);
        assert_eq!(pool.spares(), 0, "pooled decode must draw from the pool");

        // Range tiling reproduces the full decode bitwise — including the
        // absolute overwrite of the m^{g,t-1} baseline each tile starts from.
        let rd = codec
            .range_decoder(&plain.bytes, &dec_ctx)
            .unwrap()
            .expect("sparse-rsn records support range decoding");
        let mut tiled = mg.clone();
        let cuts = [0usize, 1, 2, 2, d / 3, d / 2 + 7, d];
        for w in cuts.windows(2) {
            let (lo, hi) = (w[0], w[1]);
            rd.decode_range(lo..hi, &mut tiled[lo..hi]);
        }
        assert_eq!(tiled, want);
    }

    #[test]
    fn empty_and_full_supermask_roundtrip() {
        let d = 1000;
        let mask_g = vec![0.0f32; d];
        let codec = SparseRsnCodec::default();
        let dec_ctx = DecodeCtx {
            d,
            mask_g: &mask_g,
            s_g: &[],
            seed: 99,
        };
        // All entries below λ → empty supermask.
        let theta = vec![0.1f32; d];
        let mask_k = vec![1.0f32; d];
        let ctx = make_ctx(d, &theta, &theta, &mask_k, &mask_g, 1.0);
        let enc = codec.encode(&ctx).unwrap();
        let Update::Mask(m) = codec.decode(&enc.bytes, &dec_ctx).unwrap() else {
            panic!()
        };
        assert!(m.iter().all(|&x| x == 0.0));
        // All entries active → full supermask.
        let theta = vec![0.9f32; d];
        let ctx = make_ctx(d, &theta, &theta, &mask_k, &mask_g, 1.0);
        let enc = codec.encode(&ctx).unwrap();
        let Update::Mask(m) = codec.decode(&enc.bytes, &dec_ctx).unwrap() else {
            panic!()
        };
        assert!(m.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn malformed_records_error_instead_of_panicking() {
        let d = 10_000;
        let (tk, tg, mk, mg) = setup(d, 0.1, 44);
        let codec = SparseRsnCodec::default();
        let ctx = make_ctx(d, &tk, &tg, &mk, &mg, 1.0);
        let enc = codec.encode(&ctx).unwrap();
        let dec_ctx = DecodeCtx {
            d,
            mask_g: &mg,
            s_g: &[],
            seed: 99,
        };
        // Wrong record tag (v1 filter, codec 9, codec 10), version, polarity.
        for tag in [0u8, super::super::deltamask_pco::RECORD_TAG, super::super::maskrn::RECORD_TAG]
        {
            let mut bad = enc.bytes.clone();
            bad[0] = tag;
            assert!(codec.decode(&bad, &dec_ctx).is_err(), "tag={tag}");
        }
        let mut bad = enc.bytes.clone();
        bad[1] = RECORD_VERSION + 1;
        assert!(codec.decode(&bad, &dec_ctx).is_err());
        let mut bad = enc.bytes.clone();
        bad[2] = 2;
        assert!(codec.decode(&bad, &dec_ctx).is_err(), "polarity 2 must be rejected");
        // Truncations.
        for cut in [0, 3, 6, enc.bytes.len() - 1] {
            assert!(codec.decode(&enc.bytes[..cut], &dec_ctx).is_err(), "cut={cut}");
        }
        // A v1 decoder must reject tag-9 records rather than misread them.
        assert!(
            super::super::DeltaMaskCodec::default()
                .decode(&enc.bytes, &dec_ctx)
                .is_err()
        );
        // And d bounds the index range.
        let small_mg = vec![0.0f32; 4];
        let small_ctx = DecodeCtx {
            d: 4,
            mask_g: &small_mg,
            s_g: &[],
            seed: 99,
        };
        assert!(codec.decode(&enc.bytes, &small_ctx).is_err());
    }
}
