//! Micro-benchmark harness for the `benches/` targets (the offline vendor
//! set has no criterion). Provides warmup+repeat timing with summary stats,
//! paper-style table printing, and JSON result emission into `results/`.

use crate::util::json::Json;
use crate::util::stats;
use std::time::Instant;

/// Time `f` with `warmup` + `iters` runs; returns per-iteration seconds.
pub fn time_fn<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Vec<f64> {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        std::hint::black_box(f());
        out.push(t.elapsed().as_secs_f64());
    }
    out
}

/// Summary of a timed run.
#[derive(Clone, Debug)]
pub struct Timing {
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
}

pub fn summarize(samples: &[f64]) -> Timing {
    Timing {
        mean: stats::mean(samples),
        std: stats::std(samples),
        min: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        p50: stats::median(samples),
    }
}

/// A paper-style results table (rows printed padded; also JSON-emitted).
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        println!("\n== {} ==", self.title);
        let header: Vec<String> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
            .collect();
        println!("| {} |", header.join(" | "));
        println!(
            "|{}|",
            widths
                .iter()
                .map(|w| "-".repeat(w + 2))
                .collect::<Vec<_>>()
                .join("|")
        );
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
                .collect();
            println!("| {} |", cells.join(" | "));
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("title", Json::from_str_(&self.title));
        j.set(
            "columns",
            Json::Arr(self.columns.iter().map(|c| Json::from_str_(c)).collect()),
        );
        j.set(
            "rows",
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| Json::Arr(r.iter().map(|c| Json::from_str_(c)).collect()))
                    .collect(),
            ),
        );
        j
    }

    /// Write `results/<name>.json` (best-effort; benches run from repo root).
    pub fn save(&self, name: &str) {
        let _ = std::fs::create_dir_all("results");
        let path = format!("results/{name}.json");
        if std::fs::write(&path, self.to_json().to_string_pretty()).is_ok() {
            println!("[saved {path}]");
        }
    }
}

/// Shared bench-side experiment scaling: `--full` restores the paper's
/// round/client counts; the default keeps the whole suite CPU-tractable.
pub struct BenchScale {
    pub full: bool,
    pub rounds_iid: usize,
    pub rounds_noniid: usize,
    pub n_clients: usize,
    pub eval_every: usize,
    pub f_width: usize,
    pub batch: usize,
    pub samples_per_client: usize,
    pub test_samples: usize,
}

impl BenchScale {
    pub fn from_args(args: &crate::util::cli::Args) -> Self {
        let full = args.flag("full");
        if full {
            Self {
                full,
                rounds_iid: 100,
                rounds_noniid: 300,
                n_clients: 30,
                eval_every: 10,
                f_width: 0, // 0 = use the real architecture width
                batch: 64,
                samples_per_client: 128,
                test_samples: 1024,
            }
        } else {
            Self {
                full,
                rounds_iid: args.usize("rounds", 30),
                rounds_noniid: args.usize("rounds-noniid", 40),
                n_clients: args.usize("clients", 10),
                eval_every: args.usize("eval-every", 5),
                f_width: args.usize("width", 32),
                batch: args.usize("batch", 8),
                samples_per_client: args.usize("samples", 48),
                test_samples: args.usize("test-samples", 400),
            }
        }
    }

    /// Apply to a config (miniaturizes unless --full).
    pub fn apply(&self, mut cfg: crate::fl::ExperimentConfig) -> crate::fl::ExperimentConfig {
        cfg.n_clients = self.n_clients;
        cfg.eval_every = self.eval_every;
        cfg.samples_per_client = self.samples_per_client;
        cfg.test_samples = self.test_samples;
        if self.f_width > 0 {
            cfg = cfg.miniaturize(self.f_width, self.batch);
        }
        cfg
    }

    /// Paper-defaults config for one (dataset, method) cell, IID split.
    /// Shards scale with the class count so many-class datasets stay
    /// learnable at the miniature width.
    pub fn config(&self, dataset: &str, method: &str) -> crate::fl::ExperimentConfig {
        let cfg = crate::fl::ExperimentConfig {
            dataset: dataset.into(),
            method: method.into(),
            rounds: self.rounds_iid,
            ..Default::default()
        };
        let mut cfg = self.apply(cfg);
        let classes = crate::fl::data::profile(dataset).map(|p| p.classes).unwrap_or(10);
        cfg.samples_per_client = cfg.samples_per_client.max(2 * classes);
        cfg.test_samples = cfg.test_samples.max(4 * classes);
        cfg
    }

    /// Non-IID variant: Dir(0.1) (paper §4).
    pub fn config_noniid(&self, dataset: &str, method: &str) -> crate::fl::ExperimentConfig {
        let mut cfg = self.config(dataset, method);
        cfg.dirichlet_alpha = 0.1;
        cfg.rounds = self.rounds_noniid;
        cfg
    }
}

/// The Tables 2/3 method roster, in the paper's row order.
pub fn paper_methods() -> &'static [&'static str] {
    &["linear_probing", "fine_tuning", "fedmask", "eden", "deepreduce", "fedpm", "deltamask"]
}

/// The sibling-paper mask codecs (codecs 10–11): appended below the paper
/// roster in the scenario tables to stress the non-IID / edge matrices
/// with mask methods the source paper did not evaluate.
pub fn sibling_methods() -> &'static [&'static str] {
    &["maskrn", "sparse-rsn"]
}

/// Dataset roster: the quick default covers 4 contrasting datasets, --all or
/// --full runs the paper's 8.
pub fn bench_datasets(args: &crate::util::cli::Args) -> Vec<&'static str> {
    if args.flag("full") || args.flag("all") {
        vec!["cifar10", "cifar100", "svhn", "emnist", "fmnist", "eurosat", "food101", "cars196"]
    } else {
        vec!["cifar10", "cifar100", "svhn", "eurosat"]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_and_table() {
        let samples = time_fn(1, 5, || (0..1000u64).sum::<u64>());
        assert_eq!(samples.len(), 5);
        let t = summarize(&samples);
        assert!(t.min <= t.mean + 1e-12);
        let mut tab = Table::new("t", &["a", "b"]);
        tab.row(vec!["1".into(), "2".into()]);
        let j = tab.to_json().to_string_compact();
        assert!(j.contains("\"rows\""));
    }
}
